//! Minimal-but-complete JSON codec (substrate — no `serde` in this environment).
//!
//! Parses/serializes the full JSON grammar (RFC 8259): objects, arrays,
//! strings with escapes (incl. `\uXXXX` + surrogate pairs), numbers, bools,
//! null. Used for the AOT `manifest.json`, experiment configs, and metrics
//! output. Preserves object insertion order (important for stable manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered key list + map for O(log n) lookup.
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

/// Parse error with byte offset + line/col context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.get("model")?.get("layers")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(idx))
    }

    // ---------------- constructors ----------------

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // ---------------- serialization ----------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with `indent` spaces.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(indent), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no NaN/Inf; emit null (documented lossy case).
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\x08' => out.push_str("\\b"),
            '\x0c' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        JsonError {
            msg: msg.into(),
            offset: self.pos,
            line,
            col,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!(
                "expected '{}', found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            other => Err(self.err(format!("unexpected {:?}", other.map(|c| c as char)))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                other => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err(format!(
                        "expected ',' or '}}' in object, found {:?}",
                        other.map(|c| c as char)
                    )));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                other => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err(format!(
                        "expected ',' or ']' in array, found {:?}",
                        other.map(|c| c as char)
                    )));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\x08'),
                    Some(b'f') => s.push('\x0c'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must be followed by \uDCxx.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    other => {
                        return Err(self.err(format!(
                            "invalid escape \\{:?}",
                            other.map(|c| c as char)
                        )))
                    }
                },
                Some(b) if b < 0x20 => return Err(self.err("raw control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("bad utf-8 lead byte"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number {text:?}")))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": {"d": true}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().at(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("a").unwrap().at(1).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\ud800\"").is_err()); // unpaired surrogate
    }

    #[test]
    fn error_position() {
        let e = Json::parse("{\n  \"a\": nope\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col > 1);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":{"layers":8,"dims":[3072,256,10]},"ok":true,"name":"fed\"pairing"}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty(2);
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn object_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn accessor_type_mismatches_are_none() {
        let j = Json::parse(r#"{"a": "s"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), None);
        assert_eq!(j.get("missing"), None);
        assert_eq!(j.as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_i64(), None);
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        let j = Json::parse(&s).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
