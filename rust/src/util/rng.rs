//! Deterministic pseudo-random number generation (substrate — no `rand` crate).
//!
//! Implements PCG64 (O'Neill's permuted congruential generator, XSL-RR 128/64
//! variant) seeded through SplitMix64, plus the distribution helpers the
//! simulator and data generator need: uniform ranges, normals (Box–Muller),
//! Fisher–Yates shuffles and weighted choice.
//!
//! Every stochastic component of the system (client placement, CPU frequency
//! draws, data synthesis, partitioning, batch order, pairing tie-breaks) takes
//! an explicit `Rng`, so entire experiments replay bit-identically from one
//! seed — a property `tests/` relies on heavily.

/// SplitMix64: used to expand a `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 (XSL-RR 128/64) — 128-bit state LCG with an output permutation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Rng {
    /// Create a generator from a `u64` seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator with an explicit stream id; distinct streams from
    /// the same seed are independent (used to give each client its own RNG).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let mut sm2 = stream ^ 0xDA3E_39CB_94B9_5BDB;
        let i0 = splitmix64(&mut sm2) as u128;
        let i1 = splitmix64(&mut sm2) as u128;
        let mut rng = Rng {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1, // must be odd
            spare_normal: None,
        };
        // Warm up: decorrelates low-entropy seeds.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::with_stream(self.next_u64(), stream)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR: xor-shift-low, random rotate.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform `u64` in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.next_below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (caches the second sample).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with explicit mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index choice proportional to `weights` (must be non-negative,
    /// not all zero).
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "choose_weighted: weights sum to {total}");
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            if t < w {
                return i;
            }
            t -= w;
        }
        weights.len() - 1 // floating-point slack
    }

    /// Sample from a symmetric Dirichlet(α) over `n` categories
    /// (via Gamma(α,1) draws, Marsaglia–Tsang; used by the Non-IID partitioner).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            // Degenerate underflow at tiny α: put all mass on one category.
            let mut out = vec![0.0; n];
            out[self.below(n)] = 1.0;
            return out;
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Gamma(shape, 1) sampler (Marsaglia–Tsang, with the α<1 boost).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Rng::with_stream(7, 0);
        let mut b = Rng::with_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            let expected = n / 7;
            assert!(
                (c as f64 - expected as f64).abs() < expected as f64 * 0.1,
                "count {c} vs {expected}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 30);
        assert!(u.iter().all(|&i| i < 100));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(9);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "alpha={alpha} sum={s}");
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_effect() {
        // Small α → spiky; large α → near-uniform.
        let mut r = Rng::new(10);
        let spiky: f64 = (0..50)
            .map(|_| r.dirichlet(0.05, 10).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 50.0;
        let flat: f64 = (0..50)
            .map(|_| r.dirichlet(100.0, 10).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 50.0;
        assert!(spiky > 0.6, "spiky={spiky}");
        assert!(flat < 0.2, "flat={flat}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(11);
        for &shape in &[0.5, 1.0, 3.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.05 * shape.max(1.0), "shape={shape} mean={mean}");
        }
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut r = Rng::new(12);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(13);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
