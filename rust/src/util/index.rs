//! Reusable universe→compact id inversion (scratch map).
//!
//! The scenario drivers hand the latency layer a *compact* view of this
//! round's participants (`FleetView`), but matchings store *universe* ids.
//! Inverting that mapping with `members.binary_search(&u)` costs O(log n) per
//! lookup and nothing is reused round to round. [`InverseIndex`] is the
//! zero-allocation replacement: one `rebuild` per round (O(members), reusing
//! the same buffers via a generation stamp — no clearing), then O(1) lookups.

/// Generation-stamped inverse map from universe id to compact index.
#[derive(Clone, Debug, Default)]
pub struct InverseIndex {
    slot: Vec<u32>,
    stamp: Vec<u32>,
    gen: u32,
}

impl InverseIndex {
    pub fn new() -> InverseIndex {
        InverseIndex::default()
    }

    /// Point the index at this round's `members` (compact index `c` ↔
    /// universe id `members[c]`). Amortized zero-allocation: buffers grow to
    /// `universe_n` once and are invalidated by bumping the generation.
    pub fn rebuild(&mut self, universe_n: usize, members: &[usize]) {
        assert!(members.len() <= u32::MAX as usize, "fleet too large for u32 index");
        if self.slot.len() < universe_n {
            self.slot.resize(universe_n, 0);
            self.stamp.resize(universe_n, 0);
        }
        if self.gen == u32::MAX {
            // Stamp wrap: reset so stale stamps can't collide with a reused
            // generation value. Happens once per 2^32 rebuilds.
            self.stamp.fill(0);
            self.gen = 0;
        }
        self.gen += 1;
        for (c, &u) in members.iter().enumerate() {
            self.slot[u] = c as u32;
            self.stamp[u] = self.gen;
        }
    }

    /// Compact index of universe id `u` in the current generation, if present.
    #[inline]
    pub fn get(&self, u: usize) -> Option<usize> {
        if u < self.slot.len() && self.stamp[u] == self.gen && self.gen != 0 {
            Some(self.slot[u] as usize)
        } else {
            None
        }
    }

    /// [`InverseIndex::get`] for ids known to be present (panics otherwise).
    #[inline]
    pub fn compact(&self, u: usize) -> usize {
        self.get(u)
            .unwrap_or_else(|| panic!("universe id {u} not in the current member set"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_members_to_their_positions() {
        let mut idx = InverseIndex::new();
        idx.rebuild(10, &[2, 5, 9]);
        assert_eq!(idx.get(2), Some(0));
        assert_eq!(idx.get(5), Some(1));
        assert_eq!(idx.get(9), Some(2));
        assert_eq!(idx.get(3), None);
        assert_eq!(idx.get(42), None);
        assert_eq!(idx.compact(5), 1);
    }

    #[test]
    fn rebuild_invalidates_previous_generation() {
        let mut idx = InverseIndex::new();
        idx.rebuild(6, &[0, 1, 2]);
        idx.rebuild(6, &[4, 2]);
        assert_eq!(idx.get(0), None);
        assert_eq!(idx.get(1), None);
        assert_eq!(idx.get(4), Some(0));
        assert_eq!(idx.get(2), Some(1));
    }

    #[test]
    fn empty_index_finds_nothing() {
        let idx = InverseIndex::new();
        assert_eq!(idx.get(0), None);
        let mut idx = InverseIndex::new();
        idx.rebuild(4, &[]);
        assert_eq!(idx.get(0), None);
    }

    #[test]
    fn universe_can_grow_between_rounds() {
        let mut idx = InverseIndex::new();
        idx.rebuild(3, &[1]);
        idx.rebuild(8, &[7, 1]);
        assert_eq!(idx.get(7), Some(0));
        assert_eq!(idx.get(1), Some(1));
    }

    #[test]
    #[should_panic(expected = "not in the current member set")]
    fn compact_panics_on_absent_id() {
        let mut idx = InverseIndex::new();
        idx.rebuild(4, &[0, 1]);
        idx.compact(3);
    }

    #[test]
    fn matches_binary_search_inversion() {
        // The contract with the drivers: for sorted member lists, `compact`
        // agrees with the `binary_search` inversion it replaces.
        let members: Vec<usize> = (0..200).filter(|&u| u % 3 != 1).collect();
        let mut idx = InverseIndex::new();
        idx.rebuild(200, &members);
        for &u in &members {
            assert_eq!(idx.compact(u), members.binary_search(&u).unwrap());
        }
    }
}
