//! Descriptive statistics helpers used by the bench harness and metrics sinks.

/// Running summary of a sample (Welford's online algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Percentile with linear interpolation; `q` in `[0, 1]`. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median convenience wrapper.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Ordinary least squares fit `y = a + b·x`; returns `(a, b, r2)`.
/// Used by convergence-shape assertions (accuracy trend > 0, etc.).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linreg needs >= 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    (a, b, r2)
}

/// Exponential moving average smoothing (for loss-curve reporting).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    assert!((0.0..=1.0).contains(&alpha));
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_single_value() {
        let s = Summary::from_slice(&[7.0]);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.mean(), 7.0);
    }

    #[test]
    fn welford_matches_naive_on_large_offsets() {
        // Numerical-stability check: huge offset, tiny variance.
        let xs: Vec<f64> = (0..1000).map(|i| 1e9 + (i % 3) as f64).collect();
        let s = Summary::from_slice(&xs);
        let naive_mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - naive_mean).abs() < 1e-3);
        assert!(s.var() > 0.0 && s.var() < 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 0.5);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_flat() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [5.0, 5.0, 5.0];
        let (a, b, _r2) = linreg(&xs, &ys);
        assert!((a - 5.0).abs() < 1e-9);
        assert_eq!(b, 0.0);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0, 10.0], 0.5);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 5.0);
        assert_eq!(out[2], 7.5);
    }
}
