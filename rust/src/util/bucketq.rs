//! Bucket priority queue over order-preserving `f64` keys — the persistent
//! edge ordering behind the incremental matcher (DESIGN.md §10).
//!
//! The greedy matcher consumes candidate edges in `(weight desc, (i, j) asc)`
//! order. A full rebuild pays an O(E log E) sort per epoch even when almost
//! nothing changed. [`BucketQueue`] keeps that order alive *between* epochs:
//!
//! * [`weight_key`] maps an `f64` weight to a `u64` whose unsigned order is
//!   exactly `f64::total_cmp` order, so integer compares reproduce the float
//!   sort bit-for-bit (including `-0.0 < +0.0` and NaN placement).
//! * The key's top 16 bits pick one of 65 536 buckets; buckets are therefore
//!   disjoint, contiguous key ranges and descending bucket order is
//!   descending key order between buckets.
//! * Each bucket holds a sorted `main` run plus an unsorted `appendix` of
//!   recent inserts; removals tombstone in place. A bucket is re-sorted
//!   ("rescanned") lazily, on first walk after it was touched — an epoch that
//!   dirties E' of E edges re-sorts only the buckets containing those E'
//!   edges.
//! * A two-level occupancy bitmap (1024 words + 16 summary words) makes the
//!   descending walk skip empty buckets in O(1) per skip, so sparse queues
//!   walk in O(live + occupied buckets).
//!
//! Entries are `(key, a, b)` with `a < b` the edge endpoints; within a bucket
//! the sort is `(key desc, a asc, b asc)` — concatenated over descending
//! buckets this equals the global rebuild sort order exactly (the
//! quantization picks the bucket, never the order). Handles returned by
//! [`BucketQueue::insert`] are stable across rescans and are the caller's
//! link from its edge store into the queue.

use crate::telemetry::registry::Counter;

/// Number of buckets: top 16 bits of the order-preserving key.
pub const BUCKETS: usize = 1 << 16;

const TOMB: u32 = u32::MAX;
/// Appendix flag on the bucket half of a handle's location word.
const IN_APP: u32 = 1 << 16;

/// Map `w` to a `u64` whose **unsigned** order equals `f64::total_cmp`
/// order: flip all bits of negatives, flip only the sign bit of
/// non-negatives. Monotone and injective, so sorting keys descending is
/// exactly sorting weights descending under `total_cmp`.
#[inline]
pub fn weight_key(w: f64) -> u64 {
    let b = w.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1u64 << 63)
    }
}

#[derive(Clone, Copy)]
struct Entry {
    key: u64,
    a: u32,
    b: u32,
    /// Stable handle of this entry, or [`TOMB`] for a tombstone in `main`.
    h: u32,
}

#[derive(Default)]
struct Bucket {
    /// Sorted `(key desc, a asc, b asc)`, possibly holding tombstones.
    main: Vec<Entry>,
    /// Unsorted recent inserts (tombstone-free: appendix removals swap).
    app: Vec<Entry>,
    /// Tombstones in `main`.
    dead: u32,
    /// Live entries in `main` + `app`.
    live: u32,
}

/// Persistent descending-order edge queue. See module docs.
pub struct BucketQueue {
    buckets: Vec<Bucket>,
    /// Handle → `(bucket | IN_APP?, position)`.
    loc: Vec<(u32, u32)>,
    free: Vec<u32>,
    /// Bit per bucket: any live entry?
    words: Vec<u64>,
    /// Bit per word of `words`.
    summary: [u64; BUCKETS / 64 / 64],
    live: usize,
}

impl Default for BucketQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl BucketQueue {
    pub fn new() -> Self {
        BucketQueue {
            buckets: (0..BUCKETS).map(|_| Bucket::default()).collect(),
            loc: Vec::new(),
            free: Vec::new(),
            words: vec![0; BUCKETS / 64],
            summary: [0; BUCKETS / 64 / 64],
            live: 0,
        }
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    #[inline]
    fn bucket_of(key: u64) -> usize {
        (key >> 48) as usize
    }

    #[inline]
    fn mark_occupied(&mut self, b: usize) {
        self.words[b / 64] |= 1u64 << (b % 64);
        self.summary[b / 64 / 64] |= 1u64 << ((b / 64) % 64);
    }

    #[inline]
    fn mark_empty(&mut self, b: usize) {
        let w = b / 64;
        self.words[w] &= !(1u64 << (b % 64));
        if self.words[w] == 0 {
            self.summary[w / 64] &= !(1u64 << (w % 64));
        }
    }

    /// Insert edge `(a, b)` (`a < b`) with order key `key`; returns a stable
    /// handle for later [`remove`](Self::remove) /
    /// [`update_key`](Self::update_key).
    pub fn insert(&mut self, key: u64, a: u32, b: u32) -> u32 {
        debug_assert!(a < b && b < TOMB);
        let h = match self.free.pop() {
            Some(h) => h,
            None => {
                self.loc.push((0, 0));
                (self.loc.len() - 1) as u32
            }
        };
        self.place(h, key, a, b);
        crate::tm_count!(Counter::MatcherBucketInserts, 1);
        h
    }

    /// Put entry `h` into its key's bucket appendix.
    fn place(&mut self, h: u32, key: u64, a: u32, b: u32) {
        let bi = Self::bucket_of(key);
        let bucket = &mut self.buckets[bi];
        bucket.app.push(Entry { key, a, b, h });
        bucket.live += 1;
        self.loc[h as usize] = (bi as u32 | IN_APP, (self.buckets[bi].app.len() - 1) as u32);
        self.live += 1;
        if self.buckets[bi].live == 1 {
            self.mark_occupied(bi);
        }
    }

    /// Remove the entry behind handle `h` and retire the handle.
    pub fn remove(&mut self, h: u32) {
        self.unplace(h);
        self.free.push(h);
        crate::tm_count!(Counter::MatcherBucketRemovals, 1);
    }

    /// Detach entry `h` from its bucket without retiring the handle.
    fn unplace(&mut self, h: u32) {
        let (lw, pos) = self.loc[h as usize];
        let bi = (lw & !IN_APP) as usize;
        let bucket = &mut self.buckets[bi];
        if lw & IN_APP != 0 {
            // Appendix is unsorted: swap-remove and fix the moved entry.
            let pos = pos as usize;
            bucket.app.swap_remove(pos);
            if let Some(moved) = bucket.app.get(pos) {
                self.loc[moved.h as usize] = (bi as u32 | IN_APP, pos as u32);
            }
        } else {
            // Main is sorted: tombstone in place, compact on next rescan.
            let e = &mut bucket.main[pos as usize];
            debug_assert_eq!(e.h, h);
            e.h = TOMB;
            bucket.dead += 1;
        }
        let bucket = &mut self.buckets[bi];
        bucket.live -= 1;
        self.live -= 1;
        if bucket.live == 0 {
            // Nothing live left: drop tombstones and appendix wholesale.
            bucket.main.clear();
            bucket.app.clear();
            bucket.dead = 0;
            self.mark_empty(bi);
        }
    }

    /// Move entry `h` to a new key, keeping the handle stable.
    pub fn update_key(&mut self, h: u32, key: u64) {
        let (lw, pos) = self.loc[h as usize];
        let bi = (lw & !IN_APP) as usize;
        let (a, b, old_key) = {
            let bucket = &self.buckets[bi];
            let e = if lw & IN_APP != 0 {
                &bucket.app[pos as usize]
            } else {
                &bucket.main[pos as usize]
            };
            (e.a, e.b, e.key)
        };
        if old_key == key {
            return;
        }
        if Self::bucket_of(old_key) == bi && lw & IN_APP != 0 {
            // Same bucket, already in the (unsorted) appendix: patch in place.
            self.buckets[bi].app[pos as usize].key = key;
            return;
        }
        self.unplace(h);
        self.place(h, key, a, b);
    }

    /// Sort `bucket`'s live entries into `main`, clearing tombstones and the
    /// appendix, and refresh handle locations. No-op when already normal.
    fn normalize(&mut self, bi: usize) {
        let bucket = &mut self.buckets[bi];
        if bucket.app.is_empty() && bucket.dead == 0 {
            return;
        }
        let mut merged: Vec<Entry> = Vec::with_capacity(bucket.live as usize);
        merged.extend(bucket.main.iter().filter(|e| e.h != TOMB));
        merged.extend(bucket.app.drain(..));
        merged.sort_unstable_by(|p, q| {
            q.key
                .cmp(&p.key)
                .then_with(|| (p.a, p.b).cmp(&(q.a, q.b)))
        });
        crate::tm_count!(Counter::MatcherBucketRescans, merged.len() as u64);
        bucket.main = merged;
        bucket.dead = 0;
        debug_assert_eq!(bucket.main.len(), bucket.live as usize);
        for (pos, e) in self.buckets[bi].main.iter().enumerate() {
            self.loc[e.h as usize] = (bi as u32, pos as u32);
        }
    }

    /// Visit live entries in `(key desc, a asc, b asc)` order. Buckets
    /// touched since the last walk are re-sorted on the way. `f` returns
    /// `false` to stop early (the caller saw enough edges).
    pub fn for_each_desc(&mut self, mut f: impl FnMut(u64, u32, u32) -> bool) {
        for si in (0..self.summary.len()).rev() {
            let mut sw = self.summary[si];
            while sw != 0 {
                let wbit = 63 - sw.leading_zeros() as usize;
                sw &= !(1u64 << wbit);
                let wi = si * 64 + wbit;
                let mut w = self.words[wi];
                while w != 0 {
                    let bbit = 63 - w.leading_zeros() as usize;
                    w &= !(1u64 << bbit);
                    let bi = wi * 64 + bbit;
                    self.normalize(bi);
                    for e in &self.buckets[bi].main {
                        if e.h != TOMB && !f(e.key, e.a, e.b) {
                            return;
                        }
                    }
                }
            }
        }
    }

    /// Drop everything, keeping allocated capacity where cheap.
    pub fn clear(&mut self) {
        for si in 0..self.summary.len() {
            let mut sw = self.summary[si];
            while sw != 0 {
                let wbit = 63 - sw.leading_zeros() as usize;
                sw &= !(1u64 << wbit);
                let wi = si * 64 + wbit;
                let mut w = self.words[wi];
                self.words[wi] = 0;
                while w != 0 {
                    let bbit = 63 - w.leading_zeros() as usize;
                    w &= !(1u64 << bbit);
                    let bucket = &mut self.buckets[wi * 64 + bbit];
                    bucket.main.clear();
                    bucket.app.clear();
                    bucket.dead = 0;
                    bucket.live = 0;
                }
            }
            self.summary[si] = 0;
        }
        self.loc.clear();
        self.free.clear();
        self.live = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    fn drain_desc(q: &mut BucketQueue) -> Vec<(u64, u32, u32)> {
        let mut out = Vec::new();
        q.for_each_desc(|k, a, b| {
            out.push((k, a, b));
            true
        });
        out
    }

    /// Reference order: `(key desc, a asc, b asc)`.
    fn ref_sorted(set: &BTreeSet<(u64, u32, u32)>) -> Vec<(u64, u32, u32)> {
        let mut v: Vec<_> = set.iter().copied().collect();
        v.sort_unstable_by(|p, q| q.0.cmp(&p.0).then_with(|| (p.1, p.2).cmp(&(q.1, q.2))));
        v
    }

    #[test]
    fn weight_key_orders_like_total_cmp() {
        let vals = [
            f64::NEG_INFINITY,
            -1e300,
            -1.0,
            -1e-320, // subnormal
            -0.0,
            0.0,
            1e-320,
            1.0,
            1e300,
            f64::INFINITY,
            f64::NAN,
            -f64::NAN,
        ];
        for &x in &vals {
            for &y in &vals {
                assert_eq!(
                    weight_key(x).cmp(&weight_key(y)),
                    x.total_cmp(&y),
                    "x={x:?} y={y:?}"
                );
            }
        }
        // -0.0 and +0.0 are distinct keys in total_cmp order.
        assert!(weight_key(-0.0) < weight_key(0.0));
    }

    #[test]
    fn matches_reference_under_random_churn() {
        let mut rng = Rng::new(0xB0C4);
        let mut q = BucketQueue::new();
        let mut reference: BTreeSet<(u64, u32, u32)> = BTreeSet::new();
        let mut handles: Vec<(u32, (u64, u32, u32))> = Vec::new();
        for step in 0..2000u32 {
            let op = rng.below(10);
            if op < 6 || handles.is_empty() {
                // Insert: weights clustered so buckets collide.
                let w = (rng.f64() - 0.5) * if rng.below(2) == 0 { 1.0 } else { 1e6 };
                let a = rng.below(500) as u32;
                let b = a + 1 + rng.below(500) as u32;
                let k = weight_key(w);
                if reference.insert((k, a, b)) {
                    let h = q.insert(k, a, b);
                    handles.push((h, (k, a, b)));
                }
            } else if op < 8 {
                let ix = rng.below(handles.len() as u64) as usize;
                let (h, e) = handles.swap_remove(ix);
                q.remove(h);
                reference.remove(&e);
            } else {
                let ix = rng.below(handles.len() as u64) as usize;
                let (h, e) = handles[ix];
                let k2 = weight_key((rng.f64() - 0.5) * 3.0);
                let e2 = (k2, e.1, e.2);
                if e2 == e || reference.contains(&e2) {
                    continue;
                }
                q.update_key(h, k2);
                reference.remove(&e);
                reference.insert(e2);
                handles[ix] = (h, e2);
            }
            assert_eq!(q.len(), reference.len(), "step {step}");
            // Walk (and thus normalize) periodically, not every step, so
            // appendix/tombstone paths actually accumulate state.
            if step % 37 == 0 {
                assert_eq!(drain_desc(&mut q), ref_sorted(&reference), "step {step}");
            }
        }
        assert_eq!(drain_desc(&mut q), ref_sorted(&reference));
        q.clear();
        assert!(q.is_empty());
        assert!(drain_desc(&mut q).is_empty());
    }

    #[test]
    fn early_exit_stops_walk() {
        let mut q = BucketQueue::new();
        for i in 0..100u32 {
            q.insert(weight_key(i as f64), i, i + 1);
        }
        let mut seen = 0;
        q.for_each_desc(|_, _, _| {
            seen += 1;
            seen < 10
        });
        assert_eq!(seen, 10);
    }

    #[test]
    fn ties_order_by_endpoints_ascending() {
        let mut q = BucketQueue::new();
        let k = weight_key(1.5);
        q.insert(k, 5, 9);
        q.insert(k, 1, 7);
        q.insert(k, 1, 3);
        q.insert(k, 5, 6);
        let got = drain_desc(&mut q);
        assert_eq!(
            got,
            vec![(k, 1, 3), (k, 1, 7), (k, 5, 6), (k, 5, 9)]
        );
    }
}
