//! Leveled, timestamped logging (substrate — no `log`/`env_logger` wiring).
//!
//! A tiny global logger with compile-out-able macros. Level is set once at
//! startup (CLI `--log-level` or `FEDPAIRING_LOG`); output goes to stderr so
//! metric streams on stdout stay machine-readable.
//!
//! Timestamps default to monotonic elapsed-since-init (`[+1.042 …]`) — the
//! init instant is captured once, on [`init_from_env`] or the first emit,
//! whichever comes first — so log deltas are immune to wall-clock steps.
//! `FEDPAIRING_LOG_TS=epoch` (or [`set_timestamps`]) restores absolute Unix
//! seconds for correlating against external systems.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Timestamp rendering mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Timestamps {
    /// Monotonic seconds since logger init: `+12.345` (default).
    Elapsed = 0,
    /// Absolute Unix epoch seconds: `1754640000.123`.
    Epoch = 1,
}

static TS_MODE: AtomicU8 = AtomicU8::new(Timestamps::Elapsed as u8);
static INIT: OnceLock<Instant> = OnceLock::new();

/// The elapsed clock's zero — captured exactly once, on the first call.
/// `init_from_env` primes it so `+0.000` means process startup rather than
/// the first log line.
pub fn init_instant() -> Instant {
    *INIT.get_or_init(Instant::now)
}

/// Select the timestamp mode (also `FEDPAIRING_LOG_TS=epoch|elapsed`).
pub fn set_timestamps(mode: Timestamps) {
    TS_MODE.store(mode as u8, Ordering::Relaxed);
}

/// Current timestamp mode.
pub fn timestamps() -> Timestamps {
    match TS_MODE.load(Ordering::Relaxed) {
        0 => Timestamps::Elapsed,
        _ => Timestamps::Epoch,
    }
}

/// Set the global level (also reads `FEDPAIRING_LOG` at startup via `init`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from the `FEDPAIRING_LOG` / `FEDPAIRING_LOG_TS` env vars (if
/// present) and pin the elapsed clock's zero to now.
pub fn init_from_env() {
    init_instant();
    if let Ok(v) = std::env::var("FEDPAIRING_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
    match std::env::var("FEDPAIRING_LOG_TS").as_deref() {
        Ok("epoch") => set_timestamps(Timestamps::Epoch),
        Ok("elapsed") => set_timestamps(Timestamps::Elapsed),
        _ => {}
    }
}

/// True when `lvl` would currently be emitted.
#[inline]
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit one log line (used by the macros; rarely called directly).
pub fn emit(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let (prefix, secs, millis) = match timestamps() {
        Timestamps::Elapsed => {
            let e = init_instant().elapsed();
            ("+", e.as_secs(), e.subsec_millis())
        }
        Timestamps::Epoch => {
            let now = SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .unwrap_or_default();
            ("", now.as_secs(), now.subsec_millis())
        }
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{prefix}{secs}.{millis:03} {} {}] {}",
        lvl.tag(),
        module,
        args
    );
}

/// `log!(Level::Info, "x = {}", 3)`
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($lvl, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Error, $($arg)*) }; }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Warn, $($arg)*) }; }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Info, $($arg)*) }; }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Debug, $($arg)*) }; }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Trace, $($arg)*) }; }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates_emission() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error); // silence output during tests
        log_info!("hidden {}", 1);
        log_error!("visible-but-harmless {}", 2);
        set_level(Level::Info);
    }

    #[test]
    fn init_instant_is_cached_once() {
        let a = init_instant();
        let b = init_instant();
        assert_eq!(a, b);
        assert!(a.elapsed() >= std::time::Duration::ZERO);
    }

    #[test]
    fn timestamp_mode_roundtrips() {
        set_timestamps(Timestamps::Epoch);
        assert_eq!(timestamps(), Timestamps::Epoch);
        set_timestamps(Timestamps::Elapsed); // restore the default mode
        assert_eq!(timestamps(), Timestamps::Elapsed);
    }
}
