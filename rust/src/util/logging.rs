//! Leveled, timestamped logging (substrate — no `log`/`env_logger` wiring).
//!
//! A tiny global logger with compile-out-able macros. Level is set once at
//! startup (CLI `--log-level` or `FEDPAIRING_LOG`); output goes to stderr so
//! metric streams on stdout stay machine-readable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level (also reads `FEDPAIRING_LOG` at startup via `init`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from the `FEDPAIRING_LOG` env var (if present).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("FEDPAIRING_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

/// True when `lvl` would currently be emitted.
#[inline]
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit one log line (used by the macros; rarely called directly).
pub fn emit(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{secs}.{millis:03} {} {}] {}",
        lvl.tag(),
        module,
        args
    );
}

/// `log!(Level::Info, "x = {}", 3)`
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($lvl, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Error, $($arg)*) }; }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Warn, $($arg)*) }; }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Info, $($arg)*) }; }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Debug, $($arg)*) }; }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Trace, $($arg)*) }; }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates_emission() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error); // silence output during tests
        log_info!("hidden {}", 1);
        log_error!("visible-but-harmless {}", 2);
        set_level(Level::Info);
    }
}
