//! # FedPairing
//!
//! A production-shaped reproduction of *"Effectively Heterogeneous Federated
//! Learning: A Pairing and Split Learning Based Approach"* (Shen et al., 2023)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the coordination contribution: client pairing
//!   ([`pairing`]), cost-aware split planning ([`split`]), the
//!   split-training protocol and round loop
//!   ([`coordinator`]), the heterogeneity/latency simulator ([`sim`]), the
//!   fleet-dynamics layer — churn, fading channels, incremental re-pairing —
//!   ([`fleet`]), mid-round fault injection and recovery ([`faults`]), data
//!   synthesis and partitioning ([`data`]), and host-side parameter math
//!   ([`nn`]).
//! - **L2/L1 (build-time Python)** — the model's forward/backward (JAX) with
//!   Pallas kernels at the hot spot, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] executes via the PJRT CPU client. Python never runs on
//!   the training path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod asyncsim;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod faults;
pub mod fleet;
pub mod model;
pub mod nn;
pub mod pairing;
pub mod runtime;
pub mod sim;
pub mod split;
pub mod telemetry;
pub mod util;
