//! Split planning — cost-aware cut-point optimization for FedPairing pairs
//! (DESIGN.md §7).
//!
//! The paper splits the model proportionally to raw compute
//! (`split_lengths(f_i, f_j, W)`), which assumes every layer costs the same
//! and ignores the activation bytes that cross the pair link — yet
//! [`ModelProfile`] already tabulates per-layer FLOPs and activation sizes,
//! and the latency kernels price both exactly. Related work treats the cut
//! point as an optimization variable solved jointly with resource allocation
//! (arXiv:2307.11532) and per heterogeneous pair (arXiv:2411.13907). This
//! subsystem closes that gap with three policies behind one
//! [`SplitPlanner`] interface:
//!
//! * **`Paper`** — reproduces `split_lengths` bit-for-bit. The default: all
//!   existing presets keep bit-identical traces.
//! * **`Balanced`** — equalizes per-side training FLOP-*time*
//!   (`flops(0,c)/f_i ≈ flops(c,W)/f_j`) using the real profile, so a cut
//!   through a cheap stem layer is no longer counted like a cut through a
//!   512-channel block.
//! * **`Optimal`** — exact argmin of the pair's analytic training makespan
//!   over every feasible cut, evaluated with the round engine's
//!   `two_chain_shop` kernel (O(1)-per-batch event recurrence), so compute,
//!   link contention *and* activation traffic all shape the decision. Since
//!   the search space contains the paper's cut (at the default
//!   `min_layers = 1`), `Optimal` is never slower than `Paper` under the
//!   analytic kernel — a pinned property (`rust/tests/split_planning.rs`).
//!
//! Memoization: the per-pair search depends only on
//! `(f_i, f_j, n_i, n_j, pair rate)` plus the (profile, schedule, compute,
//! split-config) context. Inside [`crate::sim::engine::RoundEngine`] the
//! engine's own cross-round memo cache covers this (its context fingerprint
//! folds the split config), so stable scenarios pay the search once. Outside
//! the engine — pairing-weight evaluation, the training drivers —
//! [`SplitCostModel`] provides the same memoization keyed on exact bit
//! patterns, one instance per (profile, schedule, compute, config) context.
//!
//! Co-design with pairing: [`SplitCostModel`] also backs
//! `EdgeWeightSpec::SplitCost`, replacing the eq. (5) proxy weight with the
//! planner's *predicted optimized pair latency* so the matcher and the
//! planner optimize the same objective (dense and sparse backends alike).

use crate::config::{ComputeConfig, SplitConfig, SplitPolicy};
use crate::sim::channel::Channel;
use crate::sim::compute::split_lengths;
use crate::sim::engine::{pair_eval_at_cut, PairEval};
use crate::sim::latency::{Fleet, Schedule};
use crate::sim::profile::ModelProfile;
use crate::telemetry::registry::Counter;
use std::cell::RefCell;
use std::collections::HashMap;

/// Everything a cut decision for one pair depends on. `f_i`/`n_i` belong to
/// the pair's *first* client — the returned cut is that client's front
/// length `L_i`; the partner holds `W − L_i`.
#[derive(Clone, Copy, Debug)]
pub struct PairContext<'a> {
    pub profile: &'a ModelProfile,
    pub sched: &'a Schedule,
    pub comp: &'a ComputeConfig,
    pub f_i_hz: f64,
    pub f_j_hz: f64,
    pub n_i: usize,
    pub n_j: usize,
    /// Pair link rate (eq. (3)), shared by both directions.
    pub rate_bps: f64,
}

/// A planner's output for one pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitDecision {
    /// Front length `L_i` of the pair's first client (`L_j = W − cut`).
    pub cut: usize,
    /// Predicted training makespan of the pair at this cut under the
    /// analytic kernel (upload excluded — it is cut-independent).
    pub predicted_round_s: f64,
}

/// A cut-point policy. Implementations must be pure functions of the
/// context so decisions are deterministic and memoizable.
pub trait SplitPlanner {
    /// Decide the cut for one pair.
    fn decide(&self, ctx: &PairContext<'_>) -> SplitDecision;
    /// Policy name (logging / output provenance).
    fn name(&self) -> &'static str;
}

/// The paper's proportional rule, bit-for-bit.
pub struct PaperPlanner;

/// FLOP-time equalization over the real profile.
pub struct BalancedPlanner {
    pub min_layers: usize,
}

/// Exact analytic-makespan argmin over all feasible cuts.
pub struct OptimalPlanner {
    pub min_layers: usize,
}

impl SplitPlanner for PaperPlanner {
    fn decide(&self, ctx: &PairContext<'_>) -> SplitDecision {
        plan(&cfg_of(SplitPolicy::Paper, 1), ctx)
    }
    fn name(&self) -> &'static str {
        "paper"
    }
}

impl SplitPlanner for BalancedPlanner {
    fn decide(&self, ctx: &PairContext<'_>) -> SplitDecision {
        plan(&cfg_of(SplitPolicy::Balanced, self.min_layers), ctx)
    }
    fn name(&self) -> &'static str {
        "balanced"
    }
}

impl SplitPlanner for OptimalPlanner {
    fn decide(&self, ctx: &PairContext<'_>) -> SplitDecision {
        plan(&cfg_of(SplitPolicy::Optimal, self.min_layers), ctx)
    }
    fn name(&self) -> &'static str {
        "optimal"
    }
}

/// `co_design` is deliberately left at its default here: it selects the
/// *pairing* objective at the call sites and never enters [`plan`] — a
/// planner's decision is identical either way.
fn cfg_of(policy: SplitPolicy, min_layers: usize) -> SplitConfig {
    SplitConfig {
        policy,
        min_layers,
        ..SplitConfig::default()
    }
}

/// The configured policy as a boxed planner.
pub fn planner_for(cfg: &SplitConfig) -> Box<dyn SplitPlanner + Send + Sync> {
    match cfg.policy {
        SplitPolicy::Paper => Box::new(PaperPlanner),
        SplitPolicy::Balanced => Box::new(BalancedPlanner {
            min_layers: cfg.min_layers,
        }),
        SplitPolicy::Optimal => Box::new(OptimalPlanner {
            min_layers: cfg.min_layers,
        }),
    }
}

// ---------------------------------------------------------------------------
// The pure planning core (shared by the trait impls, the round engine, the
// DES oracle and the drivers)
// ---------------------------------------------------------------------------

/// Plan the cut and predict the pair's training makespan.
pub fn plan(cfg: &SplitConfig, ctx: &PairContext<'_>) -> SplitDecision {
    let e = plan_eval(cfg, ctx);
    SplitDecision {
        cut: e.cut,
        predicted_round_s: e.makespan,
    }
}

/// The cut alone — skips the kernel entirely for `Paper`/`Balanced`, which
/// keeps the default policy's hot paths free of planning cost.
pub fn plan_cut(cfg: &SplitConfig, ctx: &PairContext<'_>) -> usize {
    match direct_cut(cfg, ctx) {
        Some(cut) => cut,
        None => optimal_eval(cfg, ctx).cut,
    }
}

/// Full pair evaluation at the planned cut — the round engine's miss path.
/// For `Optimal` the search's winning evaluation is returned directly, so a
/// cache miss never re-runs the kernel at the chosen cut.
pub(crate) fn plan_eval(cfg: &SplitConfig, ctx: &PairContext<'_>) -> PairEval {
    match direct_cut(cfg, ctx) {
        Some(cut) => eval_at(ctx, cut),
        None => optimal_eval(cfg, ctx),
    }
}

/// Predicted training makespan at an explicit cut — the exhaustive-search
/// oracle the property tests and the bench compare policies against.
pub fn predicted_at(ctx: &PairContext<'_>, cut: usize) -> f64 {
    eval_at(ctx, cut).makespan
}

/// Policies whose cut needs no kernel evaluation (`None` = `Optimal`).
fn direct_cut(cfg: &SplitConfig, ctx: &PairContext<'_>) -> Option<usize> {
    match cfg.policy {
        SplitPolicy::Paper => Some(split_lengths(ctx.f_i_hz, ctx.f_j_hz, ctx.profile.w()).0),
        SplitPolicy::Balanced => Some(balanced_cut(cfg, ctx)),
        SplitPolicy::Optimal => None,
    }
}

#[inline]
fn eval_at(ctx: &PairContext<'_>, cut: usize) -> PairEval {
    crate::tm_count!(Counter::KernelEvalsAnalytic, 1);
    pair_eval_at_cut(
        ctx.profile,
        ctx.sched,
        ctx.comp,
        ctx.f_i_hz,
        ctx.f_j_hz,
        ctx.n_i,
        ctx.n_j,
        ctx.rate_bps,
        cut,
    )
}

/// Feasible cut range `[lo, hi]` (inclusive) under the config's floor.
/// `validate()` guarantees `2·min_layers ≤ W`; the clamps below keep the
/// planner total even for hand-built configs.
fn cut_bounds(cfg: &SplitConfig, w: usize) -> (usize, usize) {
    let lo = cfg.min_layers.max(1).min(w - 1);
    let hi = w.saturating_sub(cfg.min_layers).clamp(lo, w - 1);
    (lo, hi)
}

/// Argmin over `c` of `|flops(0,c)/f_i − flops(c,W)/f_j|` — the profile-aware
/// generalization of the paper's layer-count proportionality. Ties break to
/// the shallowest cut (deterministic). O(W) via an incremental prefix sum.
fn balanced_cut(cfg: &SplitConfig, ctx: &PairContext<'_>) -> usize {
    let w = ctx.profile.w();
    let (lo, hi) = cut_bounds(cfg, w);
    let total = ctx.profile.train_flops(0, w);
    let mut front = ctx.profile.train_flops(0, lo);
    let mut best = lo;
    let mut best_gap = f64::INFINITY;
    for c in lo..=hi {
        if c > lo {
            front += ctx.profile.train_flops(c - 1, c);
        }
        let gap = (front / ctx.f_i_hz - (total - front) / ctx.f_j_hz).abs();
        if gap < best_gap {
            best_gap = gap;
            best = c;
        }
    }
    best
}

/// Exhaustive argmin of the analytic pair makespan over `[lo, hi]`. Strict
/// `<` keeps the shallowest cut on ties (deterministic); with the default
/// floor the paper's cut is inside the range, so the minimum can never
/// exceed the paper policy's makespan.
fn optimal_eval(cfg: &SplitConfig, ctx: &PairContext<'_>) -> PairEval {
    let w = ctx.profile.w();
    let (lo, hi) = cut_bounds(cfg, w);
    let mut best = eval_at(ctx, lo);
    for c in (lo + 1)..=hi {
        let e = eval_at(ctx, c);
        if e.makespan < best.makespan {
            best = e;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Memoizing front-end for the non-engine call sites
// ---------------------------------------------------------------------------

/// Entries beyond this are dropped wholesale — bounds the memo under
/// long-running fading scenarios where every round re-keys every pair.
const MEMO_MAX: usize = 1 << 20;

/// Memo key: exact bit patterns of the per-pair inputs (the profile /
/// schedule / compute / split-config context is fixed per model instance).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    f_i: u64,
    f_j: u64,
    n_i: u64,
    n_j: u64,
    rate: u64,
}

/// A planning context bound to one (profile, schedule, compute, config)
/// tuple, with cross-call memoization — the planner the pairing weights
/// ([`crate::pairing::EdgeWeightSpec::SplitCost`]) and the training drivers
/// share so stable fleets pay each pair's cut search once.
#[derive(Debug)]
pub struct SplitCostModel {
    profile: ModelProfile,
    sched: Schedule,
    comp: ComputeConfig,
    cfg: SplitConfig,
    memo: RefCell<HashMap<PlanKey, SplitDecision>>,
}

impl SplitCostModel {
    pub fn new(
        profile: ModelProfile,
        sched: Schedule,
        comp: ComputeConfig,
        cfg: SplitConfig,
    ) -> SplitCostModel {
        SplitCostModel {
            profile,
            sched,
            comp,
            cfg,
            memo: RefCell::new(HashMap::new()),
        }
    }

    pub fn config(&self) -> &SplitConfig {
        &self.cfg
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    /// Memoized plan from raw pair state.
    pub fn decide_raw(
        &self,
        f_i: f64,
        f_j: f64,
        n_i: usize,
        n_j: usize,
        rate: f64,
    ) -> SplitDecision {
        let key = PlanKey {
            f_i: f_i.to_bits(),
            f_j: f_j.to_bits(),
            n_i: n_i as u64,
            n_j: n_j as u64,
            rate: rate.to_bits(),
        };
        if let Some(d) = self.memo.borrow().get(&key) {
            return *d;
        }
        let d = plan(
            &self.cfg,
            &PairContext {
                profile: &self.profile,
                sched: &self.sched,
                comp: &self.comp,
                f_i_hz: f_i,
                f_j_hz: f_j,
                n_i,
                n_j,
                rate_bps: rate,
            },
        );
        let mut memo = self.memo.borrow_mut();
        if memo.len() >= MEMO_MAX {
            memo.clear();
        }
        memo.insert(key, d);
        d
    }

    /// Memoized plan for a fleet pair, pricing the link with `channel`.
    pub fn decide(&self, fleet: &Fleet, channel: &Channel, a: usize, b: usize) -> SplitDecision {
        let rate = channel.rate(&fleet.positions[a], &fleet.positions[b]);
        self.decide_raw(
            fleet.freqs_hz[a],
            fleet.freqs_hz[b],
            fleet.n_samples[a],
            fleet.n_samples[b],
            rate,
        )
    }

    /// The co-designed pairing objective: predicted optimized pair seconds.
    pub fn predicted_pair_s(&self, fleet: &Fleet, channel: &Channel, a: usize, b: usize) -> f64 {
        self.decide(fleet, channel, a, b).predicted_round_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Fleet, Channel, ModelProfile, Schedule, ComputeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(seed));
        (
            fleet,
            Channel::new(ChannelConfig::default()),
            ModelProfile::resnet18_cifar(),
            Schedule {
                batch_size: 32,
                epochs: 2,
            },
            cfg.compute,
        )
    }

    fn ctx_for<'a>(
        fleet: &Fleet,
        channel: &Channel,
        profile: &'a ModelProfile,
        sched: &'a Schedule,
        comp: &'a ComputeConfig,
        i: usize,
        j: usize,
    ) -> PairContext<'a> {
        PairContext {
            profile,
            sched,
            comp,
            f_i_hz: fleet.freqs_hz[i],
            f_j_hz: fleet.freqs_hz[j],
            n_i: fleet.n_samples[i],
            n_j: fleet.n_samples[j],
            rate_bps: channel.rate(&fleet.positions[i], &fleet.positions[j]),
        }
    }

    #[test]
    fn paper_planner_matches_split_lengths_bit_for_bit() {
        let (fleet, ch, profile, sched, comp) = setup(12, 3);
        for i in 0..fleet.n() {
            for j in 0..fleet.n() {
                if i == j {
                    continue;
                }
                let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, i, j);
                let d = PaperPlanner.decide(&ctx);
                let (l_i, l_j) = split_lengths(fleet.freqs_hz[i], fleet.freqs_hz[j], profile.w());
                assert_eq!(d.cut, l_i);
                assert_eq!(profile.w() - d.cut, l_j);
            }
        }
    }

    #[test]
    fn optimal_never_slower_and_is_the_exhaustive_argmin() {
        let (fleet, ch, profile, sched, comp) = setup(10, 7);
        let cfg = cfg_of(SplitPolicy::Optimal, 1);
        for k in 0..fleet.n() / 2 {
            let (i, j) = (2 * k, 2 * k + 1);
            let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, i, j);
            let opt = plan(&cfg, &ctx);
            let paper = PaperPlanner.decide(&ctx);
            assert!(
                opt.predicted_round_s <= paper.predicted_round_s + 1e-9,
                "optimal {} slower than paper {}",
                opt.predicted_round_s,
                paper.predicted_round_s
            );
            // Exhaustive check against every feasible cut.
            for c in 1..profile.w() {
                assert!(
                    opt.predicted_round_s <= predicted_at(&ctx, c) + 1e-12,
                    "cut {c} beats the argmin"
                );
            }
            assert_eq!(opt.predicted_round_s, predicted_at(&ctx, opt.cut));
        }
    }

    #[test]
    fn balanced_beats_paper_on_flop_imbalance() {
        // On the non-uniform ResNet profile the FLOP-time gap of the
        // balanced cut is never worse than the paper cut's.
        let (fleet, ch, profile, sched, comp) = setup(16, 11);
        let w = profile.w();
        let total = profile.train_flops(0, w);
        let gap = |cut: usize, f_i: f64, f_j: f64| {
            let front = profile.train_flops(0, cut);
            (front / f_i - (total - front) / f_j).abs()
        };
        for k in 0..fleet.n() / 2 {
            let (i, j) = (2 * k, 2 * k + 1);
            let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, i, j);
            let b = BalancedPlanner { min_layers: 1 }.decide(&ctx);
            let p = PaperPlanner.decide(&ctx);
            let (f_i, f_j) = (fleet.freqs_hz[i], fleet.freqs_hz[j]);
            assert!(gap(b.cut, f_i, f_j) <= gap(p.cut, f_i, f_j) + 1e-9);
        }
    }

    #[test]
    fn min_layers_floor_is_respected() {
        let (fleet, ch, profile, sched, comp) = setup(8, 5);
        let w = profile.w();
        for policy in [SplitPolicy::Balanced, SplitPolicy::Optimal] {
            let cfg = cfg_of(policy, 3);
            for k in 0..fleet.n() / 2 {
                let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, 2 * k, 2 * k + 1);
                let cut = plan_cut(&cfg, &ctx);
                assert!((3..=w - 3).contains(&cut), "{policy:?}: cut {cut}");
            }
        }
    }

    #[test]
    fn cost_model_memoizes_deterministically() {
        let (fleet, ch, profile, sched, comp) = setup(6, 9);
        let model = SplitCostModel::new(
            profile.clone(),
            sched,
            comp,
            cfg_of(SplitPolicy::Optimal, 1),
        );
        let a = model.decide(&fleet, &ch, 0, 1);
        let b = model.decide(&fleet, &ch, 0, 1); // memo hit
        assert_eq!(a, b);
        // Matches the unmemoized plan exactly.
        let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, 0, 1);
        assert_eq!(a, plan(&cfg_of(SplitPolicy::Optimal, 1), &ctx));
        assert_eq!(
            model.predicted_pair_s(&fleet, &ch, 0, 1),
            a.predicted_round_s
        );
    }

    #[test]
    fn planner_factory_dispatches_by_policy() {
        let (fleet, ch, profile, sched, comp) = setup(4, 13);
        let ctx = ctx_for(&fleet, &ch, &profile, &sched, &comp, 0, 1);
        for (policy, name) in [
            (SplitPolicy::Paper, "paper"),
            (SplitPolicy::Balanced, "balanced"),
            (SplitPolicy::Optimal, "optimal"),
        ] {
            let cfg = cfg_of(policy, 1);
            let p = planner_for(&cfg);
            assert_eq!(p.name(), name);
            assert_eq!(p.decide(&ctx), plan(&cfg, &ctx));
            let cut = p.decide(&ctx).cut;
            assert!((1..profile.w()).contains(&cut));
        }
    }
}
