//! Engine-free scenario runs: the full fleet-dynamics × pairing × latency
//! pipeline without model training.
//!
//! Training against the AOT artifacts needs the XLA backend; everything the
//! *fleet* layer contributes — churn traces, incremental re-pairing, per-round
//! latency under fading channels, alive-client accounting — does not. This
//! driver runs any algorithm's latency loop under any scenario and emits a
//! regular [`RunResult`] (accuracy fields are NaN, exactly like skipped-eval
//! rounds), so the CLI, examples and benches share the metrics sinks with the
//! real training path.

use super::dynamics::{FleetDynamics, RoundEvents};
use super::{maintain_matching_session, PairingSession};
use crate::asyncsim::AggregationEvent;
use crate::config::{AggregationMode, Algorithm, ConfigError, ExperimentConfig, SplitPolicy};
use crate::coordinator::metrics::{streamer_for, RoundRecord, RunResult};
use crate::faults::{self, FaultModel};
use crate::sim::engine::RoundEngine;
use crate::sim::latency::{Fleet, FleetView, Schedule};
use crate::sim::profile::ModelProfile;
use crate::split::SplitCostModel;
use crate::telemetry::{Observatory, Telemetry};
use crate::util::index::InverseIndex;
use crate::util::rng::Rng;

/// A completed scenario simulation.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    /// Standard run result: per-round times, `n_alive`, config echo.
    pub result: RunResult,
    /// The full churn trace (one entry per round).
    pub trace: Vec<RoundEvents>,
    /// Rounds in which the matching was incrementally repaired.
    pub repaired_rounds: usize,
    /// Buffered-aggregation merge timeline (empty on synchronous runs).
    pub events: Vec<AggregationEvent>,
}

impl ScenarioRun {
    /// Mean participating clients per round (delegates to the result — one
    /// source of truth for the statistic).
    pub fn mean_alive(&self) -> f64 {
        self.result.mean_alive()
    }

    pub fn total_departures(&self) -> usize {
        self.trace.iter().map(|e| e.departed.len()).sum()
    }

    pub fn total_joins(&self) -> usize {
        self.trace.iter().map(|e| e.joined.len()).sum()
    }
}

/// Simulate `cfg.rounds` rounds of the configured algorithm under the
/// configured scenario (latency + churn only; no training).
pub fn simulate_scenario(cfg: &ExperimentConfig) -> Result<ScenarioRun, ConfigError> {
    cfg.validate()?;
    if cfg.aggregation == AggregationMode::Async {
        // The event-driven path shares this signature and result shape; the
        // synchronous loop below stays byte-identical to what it always was.
        return crate::asyncsim::simulate_async(cfg);
    }
    let t0 = std::time::Instant::now();
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let profile = ModelProfile::from_preset(cfg.model);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    // Pairing/splitting co-design: under a non-paper split policy the
    // Greedy/Exact pairing weights become the planner's predicted pair
    // latency (memoized per exact pair inputs).
    let cost = (cfg.split.policy != SplitPolicy::Paper && cfg.split.co_design)
        .then(|| SplitCostModel::new(profile.clone(), sched, cfg.compute, cfg.split));
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut pairing = PairingSession::new();
    let mut records = Vec::with_capacity(cfg.rounds);
    let mut trace = Vec::with_capacity(cfg.rounds);
    let mut repaired_rounds = 0usize;
    let mut sim_total = 0.0f64;
    // Round-time engine + zero-allocation round views: the per-round hot
    // path borrows the universe fleet (no `Fleet::subset` clone), inverts
    // universe→compact ids through a reusable scratch map, and evaluates
    // pairs analytically with cross-round memoization (DESIGN.md §6).
    let mut engine = RoundEngine::new(&cfg.engine).with_split(cfg.split);
    // Mid-round fault injection (DESIGN.md §11). A disarmed config skips
    // the whole pass, so fault-free traces stay bit-identical.
    let fmodel = FaultModel::new(&cfg.faults, cfg.algorithm, cfg.seed);
    // Per-unit recording is always on: the fault model replays unit times,
    // and the observatory's quantile lanes + fairness ledger land on every
    // RoundRecord. Recording is attribution-only — it never changes the
    // round arithmetic (pinned by `record_units_captures_aligned_splits`).
    engine.set_record_units(true);
    let mut observatory = Observatory::new();
    let mut inv = InverseIndex::new();
    let mut cpairs: Vec<(usize, usize)> = Vec::new();
    let mut csolos: Vec<usize> = Vec::new();
    let mut telemetry = Telemetry::new(&cfg.telemetry);
    let mut streamer =
        streamer_for(cfg).map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
    for round in 1..=cfg.rounds {
        telemetry.begin_round(round);
        let ev = dynamics.step(round);
        let channel = dynamics.channel();
        telemetry.mark("dynamics");
        let members = dynamics.present_members();
        let mut rt = match cfg.algorithm {
            Algorithm::FedPairing => {
                let had_matching = pairing.matching.is_some();
                let changed = maintain_matching_session(
                    &mut pairing,
                    &dynamics,
                    &ev,
                    &channel,
                    cfg,
                    cost.as_ref(),
                    &mut pairing_rng,
                );
                telemetry.mark("matcher");
                if had_matching && changed {
                    repaired_rounds += 1;
                }
                let view = FleetView::new(dynamics.universe(), members);
                let eff = pairing
                    .matching
                    .as_ref()
                    .expect("matching initialized")
                    .restricted_to(members);
                inv.rebuild(dynamics.universe().n(), members);
                cpairs.clear();
                cpairs.extend(
                    eff.pairs
                        .iter()
                        .map(|&(a, b)| (inv.compact(a), inv.compact(b))),
                );
                csolos.clear();
                csolos.extend(eff.solos.iter().map(|&s| inv.compact(s)));
                telemetry.mark("pairing");
                engine.fedpairing_round(
                    &view,
                    &cpairs,
                    &csolos,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    true,
                )
            }
            Algorithm::VanillaFL => {
                let view = FleetView::new(dynamics.universe(), members);
                engine.fl_round(&view, &profile, &sched, &channel, &cfg.compute, true)
            }
            Algorithm::VanillaSL => {
                let view = FleetView::new(dynamics.universe(), members);
                // In range for this profile by config validation — no clamp.
                engine.sl_round(
                    &view,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    cfg.sl_cut_layer,
                    cfg.compute.server_freq_ghz * 1e9,
                )
            }
            Algorithm::SplitFed => {
                let view = FleetView::new(dynamics.universe(), members);
                engine.splitfed_round(
                    &view,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    cfg.splitfed_cut_layer,
                    cfg.compute.server_freq_ghz * 1e9,
                    true,
                )
            }
        };
        rt.stages.remap_crit(members);
        // Fault pass: replay the round's units through the fault model and
        // take the recovered (retried / re-paired / deadline-clamped) finish
        // as the round time. Inactive models leave `rt` bit-untouched.
        let mut lost_ids: Vec<usize> = Vec::new();
        if fmodel.active() {
            let specs = match cfg.algorithm {
                Algorithm::FedPairing => {
                    let view = FleetView::new(dynamics.universe(), members);
                    faults::fedpairing_unit_specs(
                        engine.unit_times(),
                        &cpairs,
                        &csolos,
                        members,
                        &view,
                        &profile,
                        &sched,
                        &channel,
                        &cfg.compute,
                    )
                }
                algo => faults::solo_unit_specs(algo, engine.unit_times(), members),
            };
            let shared = if cfg.algorithm == Algorithm::SplitFed {
                rt.stages.stage_s[5]
            } else {
                0.0
            };
            let out = fmodel.inject_round(round, &specs, shared, rt.total_s);
            rt.total_s = out.total_s;
            rt.faults = out.counters;
            faults::note_outcome(&out.counters, &out.events);
            telemetry.fault_events(&out.events, sim_total);
            lost_ids = out.lost;
        }
        telemetry.mark("engine");
        sim_total += rt.total_s;
        // Observatory feed: side-channel only — it reads the engine's
        // recorded units and never writes back into the round arithmetic,
        // so the RoundRecord trace is independent of the telemetry gate.
        let units: Vec<(usize, Option<usize>)> = match cfg.algorithm {
            Algorithm::FedPairing => cpairs
                .iter()
                .map(|&(a, b)| (members[a], Some(members[b])))
                .chain(csolos.iter().map(|&s| (members[s], None)))
                .collect(),
            _ => members.iter().map(|&m| (m, None)).collect(),
        };
        let mk = observatory.note_sync_round(
            &units,
            engine.unit_times(),
            engine.unit_splits(),
            rt.total_s,
            &lost_ids,
        );
        observatory.note_stages(&rt.stages);
        observatory.note_fault_recovery(rt.faults.recovery_s);
        let rec = RoundRecord {
            round,
            n_alive: ev.n_alive,
            train_loss: f64::NAN,
            test_acc: f64::NAN,
            test_loss: f64::NAN,
            sim_round_s: rt.total_s,
            sim_total_s: sim_total,
            t_wall_s: sim_total,
            staleness_mean: f64::NAN,
            faults: rt.faults,
            mean_cut: rt.mean_cut,
            stages: rt.stages,
            mk_p50_s: mk.p50_s,
            mk_p90_s: mk.p90_s,
            mk_p99_s: mk.p99_s,
            fairness: observatory.ledger.jain(),
        };
        if let Some(s) = streamer.as_mut() {
            s.push(&rec)
                .map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
        }
        records.push(rec);
        // Pair lanes only ever fill on the FedPairing analytic path with
        // telemetry on; the universe-id remap is free otherwise.
        let lanes: Vec<(usize, usize, f64)> = engine
            .pair_lanes()
            .iter()
            .map(|&(a, b, t)| (members[a], members[b], t))
            .collect();
        telemetry.end_round(&rt, ev.n_alive, &lanes, sim_total - rt.total_s);
        trace.push(ev);
    }
    if let Some(s) = streamer {
        let (c, j) = s
            .finish()
            .map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
        crate::log_info!("stream: wrote {c} and {j}");
    }
    for path in telemetry
        .finish()
        .map_err(|e| ConfigError(format!("telemetry export failed: {e}")))?
    {
        crate::log_info!("telemetry: wrote {path}");
    }
    Ok(ScenarioRun {
        result: RunResult {
            config: cfg.clone(),
            rounds: records,
            wall_s: t0.elapsed().as_secs_f64(),
            total_execs: 0,
            observatory,
        },
        trace,
        repaired_rounds,
        events: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ScenarioConfig, ScenarioKind};

    fn cfg(kind: ScenarioKind, algo: Algorithm) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.n_clients = 12;
        c.rounds = 30;
        c.samples_per_client = 250;
        c.algorithm = algo;
        c.scenario = ScenarioConfig::preset(kind);
        c
    }

    #[test]
    fn all_algorithms_run_under_all_scenarios() {
        for kind in ScenarioKind::ALL {
            for algo in [
                Algorithm::FedPairing,
                Algorithm::VanillaFL,
                Algorithm::VanillaSL,
                Algorithm::SplitFed,
            ] {
                let run = simulate_scenario(&cfg(kind, algo)).unwrap();
                assert_eq!(run.result.rounds.len(), 30, "{kind:?}/{algo:?}");
                assert!(
                    run.result.rounds.iter().all(|r| r.sim_round_s > 0.0),
                    "{kind:?}/{algo:?}"
                );
                assert!(run.result.rounds.iter().all(|r| r.n_alive >= 1));
            }
        }
    }

    #[test]
    fn flash_crowd_departs_and_repairs() {
        // The acceptance-criteria path: a FedPairing run under flash-crowd
        // must see a mid-run departure, repair the matching, and record
        // per-round alive counts.
        let run = simulate_scenario(&cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing))
            .unwrap();
        assert!(run.total_departures() > 0, "no departure in 30 rounds");
        assert!(run.repaired_rounds > 0, "matching never repaired");
        assert!(run.total_joins() > 0, "flash cohort never joined");
        let alive: Vec<usize> = run.result.rounds.iter().map(|r| r.n_alive).collect();
        assert_eq!(alive.len(), 30);
        assert!(alive.iter().any(|&a| a != alive[0]), "alive never varied");
    }

    #[test]
    fn stable_scenario_times_are_constant() {
        let run = simulate_scenario(&cfg(ScenarioKind::Stable, Algorithm::FedPairing)).unwrap();
        let t0 = run.result.rounds[0].sim_round_s;
        assert!(run.result.rounds.iter().all(|r| r.sim_round_s == t0));
        assert!(run.result.rounds.iter().all(|r| r.n_alive == 12));
        assert_eq!(run.repaired_rounds, 0);
        assert_eq!(run.total_departures(), 0);
    }

    #[test]
    fn split_policies_record_cuts_and_never_slow_rounds_down() {
        use crate::config::SplitPolicy;
        let mut paper = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
        paper.rounds = 12;
        paper.split.co_design = false; // pin the pairing so rounds compare 1:1
        let mut optimal = paper.clone();
        optimal.split.policy = SplitPolicy::Optimal;
        let a = simulate_scenario(&paper).unwrap();
        let b = simulate_scenario(&optimal).unwrap();
        for (ra, rb) in a.result.rounds.iter().zip(&b.result.rounds) {
            assert!(
                rb.sim_round_s <= ra.sim_round_s + 1e-9,
                "round {}: optimal {} slower than paper {}",
                ra.round,
                rb.sim_round_s,
                ra.sim_round_s
            );
            assert!(rb.mean_cut.is_finite(), "round {}: no cut recorded", ra.round);
        }
        // FL has no cut; SL/SplitFed report the configured server cut.
        let fl = simulate_scenario(&cfg(ScenarioKind::Stable, Algorithm::VanillaFL)).unwrap();
        assert!(fl.result.rounds.iter().all(|r| r.mean_cut.is_nan()));
        let sf = simulate_scenario(&cfg(ScenarioKind::Stable, Algorithm::SplitFed)).unwrap();
        assert!(sf.result.rounds.iter().all(|r| r.mean_cut == 3.0));
        let sl = simulate_scenario(&cfg(ScenarioKind::Stable, Algorithm::VanillaSL)).unwrap();
        assert!(sl.result.rounds.iter().all(|r| r.mean_cut == 1.0));
    }

    #[test]
    fn co_designed_pairing_runs_on_deeper_models() {
        // metro-deep's ResNet-34 profile at a test-sized fleet: the full
        // co-design path (SplitCost weights + optimal cuts) stays valid.
        use crate::config::SplitPolicy;
        let mut c = cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing);
        c.model = crate::config::ModelPreset::Resnet34;
        c.rounds = 10;
        c.samples_per_client = 64;
        c.split.policy = SplitPolicy::Optimal;
        let run = simulate_scenario(&c).unwrap();
        assert_eq!(run.result.rounds.len(), 10);
        assert!(run.result.rounds.iter().all(|r| r.sim_round_s > 0.0));
        // Cuts live in the ResNet-34 range.
        assert!(run
            .result
            .rounds
            .iter()
            .all(|r| r.mean_cut >= 1.0 && r.mean_cut <= 17.0));
    }

    #[test]
    fn scenario_runs_are_deterministic() {
        let c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
        let a = simulate_scenario(&c).unwrap();
        let b = simulate_scenario(&c).unwrap();
        assert_eq!(a.trace, b.trace);
        let ta: Vec<f64> = a.result.rounds.iter().map(|r| r.sim_round_s).collect();
        let tb: Vec<f64> = b.result.rounds.iter().map(|r| r.sim_round_s).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn lossy_radio_round_times_vary_with_fading() {
        let run = simulate_scenario(&cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing))
            .unwrap();
        let times: Vec<f64> = run.result.rounds.iter().map(|r| r.sim_round_s).collect();
        assert!(times.iter().any(|&t| t != times[0]), "round times frozen");
    }

    #[test]
    fn rounds_carry_quantile_lanes_and_fairness() {
        for algo in [
            Algorithm::FedPairing,
            Algorithm::VanillaFL,
            Algorithm::VanillaSL,
            Algorithm::SplitFed,
        ] {
            let run = simulate_scenario(&cfg(ScenarioKind::Stable, algo)).unwrap();
            for r in &run.result.rounds {
                assert!(r.mk_p50_s.is_finite(), "{algo:?}: no p50 lane");
                assert!(
                    r.mk_p50_s <= r.mk_p90_s && r.mk_p90_s <= r.mk_p99_s,
                    "{algo:?}: lanes not monotone"
                );
                assert!(
                    r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12,
                    "{algo:?}: fairness {} out of range",
                    r.fairness
                );
            }
            let obs = &run.result.observatory;
            assert!(obs.unit_makespan.count() > 0, "{algo:?}: empty sketch");
            assert!(!obs.ledger.is_empty(), "{algo:?}: empty ledger");
        }
    }

    #[test]
    fn result_serializes_with_alive_counts() {
        let run = simulate_scenario(&cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing))
            .unwrap();
        let j = run.result.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 30);
        assert!(rounds.iter().all(|r| r.get("n_alive").is_some()));
    }
}
