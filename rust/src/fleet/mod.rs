//! Fleet dynamics — the fleet as a *process*, not a one-shot sample.
//!
//! The paper (and the seed reproduction) freezes the fleet at round 0: every
//! client survives all rounds and the eq. (3) channel never moves. Real edge
//! deployments churn — clients arrive, depart, fail transiently, straggle,
//! and see fading links (cf. arXiv:2411.13907, arXiv:2310.15584). This
//! subsystem makes all of that first-class while keeping the substrate's
//! determinism contract: every draw comes from dedicated `util::rng` streams,
//! so a `(seed, scenario)` pair replays bit-identically.
//!
//! * [`dynamics`] — [`FleetDynamics`]: per-round churn (arrival/departure/
//!   rejoin), transient failures, diurnal availability waves, straggler
//!   slowdowns, client mobility, and per-round log-normal shadowing layered
//!   on `sim::channel` (pairing weights go stale and must be refreshed).
//! * [`sim_driver`] — an engine-free scenario runner that produces a full
//!   [`crate::coordinator::RunResult`] from the latency simulator alone
//!   (round times + per-round alive counts, no model training), used by the
//!   `fedpairing churn` CLI, `examples/churn_fleet.rs` and the benches.
//! * [`maintain_matching`] — the shared create-or-repair step both the
//!   training drivers and the sim driver call each round: initial pairing via
//!   the configured strategy, then *incremental* repair
//!   ([`crate::pairing::repair_matching`]) when churn hits, logged at INFO.
//!
//! Scenario presets (`stable`, `diurnal`, `flash-crowd`, `lossy-radio`) live
//! in [`crate::config::ScenarioConfig`] so they load from the same JSON
//! config as everything else.

pub mod dynamics;
pub mod sim_driver;

pub use dynamics::{universe_size, FleetDynamics, RoundEvents};
pub use sim_driver::{simulate_scenario, ScenarioRun};

use crate::config::{ExperimentConfig, PairingStrategy};
use crate::log_info;
use crate::pairing::{pair_members, repair_matching, Matching};
use crate::sim::channel::Channel;
use crate::util::rng::{splitmix64, Rng};

/// Create or incrementally repair the FedPairing matching for this round.
///
/// * First call (`matching` is `None`): full pairing of the alive set via the
///   configured strategy.
/// * Later rounds: a no-op unless this round saw departures or joins; then
///   only the affected clients are re-matched on *fresh* channel weights,
///   with the repair logged at INFO.
///
/// Returns `true` when the matching changed.
pub fn maintain_matching(
    matching: &mut Option<Matching>,
    dynamics: &FleetDynamics,
    ev: &RoundEvents,
    channel: &Channel,
    cfg: &ExperimentConfig,
    pairing_rng: &mut Rng,
) -> bool {
    let alive = dynamics.alive_indices();
    match matching {
        None => {
            let m = pair_members(
                cfg.pairing,
                dynamics.universe(),
                channel,
                cfg.alpha,
                cfg.beta,
                pairing_rng,
                &alive,
            );
            log_info!(
                "round {}: initial pairing via {} — {} pair(s), {} solo",
                ev.round,
                cfg.pairing,
                m.pairs.len(),
                m.solos.len()
            );
            *matching = Some(m);
            true
        }
        Some(m) => {
            if ev.departed.is_empty() && ev.joined.is_empty() {
                return false;
            }
            let uni = dynamics.universe();
            // Repair with the *configured* mechanism's objective — repairing
            // a random/location/compute baseline with eq. (5) weights would
            // drift its matching toward the FedPairing criterion over churn.
            let nonce = pairing_rng.next_u64();
            let weight: Box<dyn Fn(usize, usize) -> f64 + '_> = match cfg.pairing {
                PairingStrategy::Greedy | PairingStrategy::Exact => Box::new(|a, b| {
                    let df = (uni.freqs_hz[a] - uni.freqs_hz[b]) / 1e9;
                    cfg.alpha * df * df
                        + cfg.beta * channel.rate(&uni.positions[a], &uni.positions[b])
                }),
                PairingStrategy::Random => Box::new(move |a, b| {
                    // Deterministic per-round pseudo-random weight.
                    let mut s = nonce ^ ((a as u64) << 32) ^ b as u64;
                    splitmix64(&mut s) as f64
                }),
                PairingStrategy::Location => {
                    Box::new(|a, b| -uni.positions[a].dist(&uni.positions[b]))
                }
                PairingStrategy::Compute => Box::new(|a, b| {
                    let df = (uni.freqs_hz[a] - uni.freqs_hz[b]) / 1e9;
                    df * df
                }),
            };
            let rep = repair_matching(m, &alive, |a, b| weight(a, b));
            if rep.changed() {
                log_info!(
                    "round {}: incremental re-pair — dropped {:?}, formed {:?}, solo {:?} \
                     ({} pair(s) untouched)",
                    ev.round,
                    rep.dropped_pairs,
                    rep.new_pairs,
                    rep.new_solos,
                    rep.kept_pairs
                );
            }
            rep.changed()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ScenarioConfig, ScenarioKind};
    use crate::sim::latency::Fleet;

    #[test]
    fn maintain_matching_initial_then_repair() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 8;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut dynamics = FleetDynamics::new(&cfg, base);
        let mut rng = Rng::new(1);
        let mut matching = None;
        let ev = dynamics.step(1);
        let ch = dynamics.channel();
        assert!(maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, &mut rng));
        let m = matching.as_ref().unwrap();
        assert!(m.is_valid_over(&dynamics.alive_indices()), "{m:?}");
        // Step until churn hits, then the matching must stay valid.
        for round in 2..=40 {
            let ev = dynamics.step(round);
            let ch = dynamics.channel();
            maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, &mut rng);
            let m = matching.as_ref().unwrap();
            assert!(
                m.is_valid_over(&dynamics.alive_indices()),
                "round {round}: {m:?}"
            );
        }
    }
}
