//! Fleet dynamics — the fleet as a *process*, not a one-shot sample.
//!
//! The paper (and the seed reproduction) freezes the fleet at round 0: every
//! client survives all rounds and the eq. (3) channel never moves. Real edge
//! deployments churn — clients arrive, depart, fail transiently, straggle,
//! and see fading links (cf. arXiv:2411.13907, arXiv:2310.15584). This
//! subsystem makes all of that first-class while keeping the substrate's
//! determinism contract: every draw comes from dedicated `util::rng` streams,
//! so a `(seed, scenario)` pair replays bit-identically.
//!
//! * [`dynamics`] — [`FleetDynamics`]: per-round churn (arrival/departure/
//!   rejoin), transient failures, diurnal availability waves, straggler
//!   slowdowns, client mobility, and per-round log-normal shadowing layered
//!   on `sim::channel` (pairing weights go stale and must be refreshed).
//! * [`sim_driver`] — an engine-free scenario runner that produces a full
//!   [`crate::coordinator::RunResult`] from the latency simulator alone
//!   (round times + per-round alive counts, no model training), used by the
//!   `fedpairing churn` CLI, `examples/churn_fleet.rs` and the benches.
//! * [`PairingSession`] / [`maintain_matching_session`] — the cross-round
//!   pairing state the drivers own and the mode-aware create-or-maintain
//!   step they call each round, dispatching on
//!   [`PairingMode`](crate::config::PairingMode): `repair` (churn-pool
//!   repair plus a cross-round pool memo), `rebuild` (full sparse-graph
//!   re-pairing every round — the reference), and `incremental` (the
//!   persistent [`IncrementalMatcher`], bit-for-bit the rebuild matching at
//!   O(affected) cost). [`maintain_matching`] keeps the historical
//!   memo-free repair behavior for callers without a session. At fleet
//!   scale (sparse backend) the initial pairing reads candidates straight
//!   off [`FleetDynamics`]' incrementally-maintained spatial grid and
//!   repair pools re-match against grid-local candidates only, so a
//!   100k-client churn round never materializes O(n²) edges.
//!
//! Scenario presets (`stable`, `diurnal`, `flash-crowd`, `lossy-radio`,
//! `metro-scale`) live in [`crate::config::ScenarioConfig`] so they load
//! from the same JSON config as everything else.

pub mod dynamics;
pub mod sim_driver;

pub use dynamics::{universe_size, FleetDynamics, RoundEvents};
pub use sim_driver::{simulate_scenario, ScenarioRun};

use crate::config::{ExperimentConfig, PairingMode};
use crate::log_info;
use crate::pairing::{
    dense_pool_matching, match_candidates, pair_members_with, repair_matching_pooled_memo,
    EdgeWeightSpec, IncrementalMatcher, Matching, RepairMemo, SparseCandidateGraph,
};
use crate::sim::channel::Channel;
use crate::split::SplitCostModel;
use crate::util::pool::FixedPool;
use crate::util::rng::{splitmix64, Rng};

/// Repair pools at most this large are matched densely (O(pool²) edges —
/// exactly right for the handful of clients a churn round touches). Larger
/// pools (metro-scale churn, flash cohorts) go through the sparse
/// candidate-graph with grid-local candidates only.
const DENSE_POOL_MAX: usize = 64;

/// Cross-round pairing state a driver owns for the length of a run: the
/// standing matching plus whatever the configured
/// [`PairingMode`](crate::config::PairingMode) keeps alive between rounds —
/// the persistent [`IncrementalMatcher`] (incremental mode) and the repair
/// pool memo (repair mode).
#[derive(Default)]
pub struct PairingSession {
    /// The standing matching (`None` until the first round pairs).
    pub matching: Option<Matching>,
    matcher: Option<IncrementalMatcher>,
    memo: RepairMemo,
}

impl PairingSession {
    pub fn new() -> PairingSession {
        PairingSession::default()
    }

    /// Churn rounds the repair-pool memo served from cache (repair mode).
    pub fn memo_hits(&self) -> u64 {
        self.memo.hits
    }

    /// Full bucket-queue solves the incremental matcher ran (incremental
    /// mode) — update epochs minus cached-matching short-circuits.
    pub fn matcher_solves(&self) -> u64 {
        self.matcher.as_ref().map_or(0, |m| m.solves)
    }
}

/// Mode-aware create-or-maintain step — dispatches on
/// [`ExperimentConfig::pairing_mode`]:
///
/// * `repair` — [`maintain_matching`]'s churn-pool path, plus the session's
///   cross-round memo: a pool the session already matched under an
///   identical weight fingerprint is replayed instead of re-solved.
/// * `rebuild` — re-runs the full sparse candidate-graph pairing every
///   round. The reference the incremental matcher is measured against.
/// * `incremental` — advances the persistent [`IncrementalMatcher`]:
///   bit-for-bit the rebuild matching, at O(affected edges) cost.
///
/// `rebuild`/`incremental` pin the sparse candidate-graph semantics at any
/// fleet size (the dense/sparse backend split applies to repair mode only),
/// so the two modes stay mutually bit-identical and comparable. Random
/// pairing has no weight objective to rebuild against — config validation
/// rejects it outside repair mode and this function routes it to repair
/// defensively.
///
/// Returns `true` when the matching changed.
pub fn maintain_matching_session(
    session: &mut PairingSession,
    dynamics: &FleetDynamics,
    ev: &RoundEvents,
    channel: &Channel,
    cfg: &ExperimentConfig,
    cost: Option<&SplitCostModel>,
    pairing_rng: &mut Rng,
) -> bool {
    let spec = EdgeWeightSpec::for_strategy_with(cfg.pairing, cfg.alpha, cfg.beta, cost);
    match (cfg.pairing_mode, spec) {
        (PairingMode::Rebuild, Some(spec)) => {
            let alive = dynamics.alive_indices();
            let g = SparseCandidateGraph::over_members_pooled(
                dynamics.universe(),
                channel,
                dynamics.grid(),
                &alive,
                spec,
                cfg.backend.k_near,
                cfg.backend.k_freq,
                &FixedPool::new(cfg.engine.threads),
            );
            adopt(session, ev, cfg, "rebuild", match_candidates(&g, &alive))
        }
        (PairingMode::Incremental, Some(spec)) => {
            let alive = dynamics.alive_indices();
            let matcher = session.matcher.get_or_insert_with(|| {
                IncrementalMatcher::new(
                    dynamics.universe().n(),
                    cfg.backend.k_near,
                    cfg.backend.k_freq,
                )
            });
            let m = matcher
                .update(
                    dynamics.universe(),
                    channel,
                    dynamics.grid(),
                    &alive,
                    &spec,
                    &FixedPool::new(cfg.engine.threads),
                )
                .clone();
            adopt(session, ev, cfg, "incremental", m)
        }
        _ => repair_step(
            &mut session.matching,
            &mut session.memo,
            dynamics,
            ev,
            channel,
            cfg,
            cost,
            pairing_rng,
        ),
    }
}

/// Install a freshly computed full matching and report whether it changed.
fn adopt(
    session: &mut PairingSession,
    ev: &RoundEvents,
    cfg: &ExperimentConfig,
    mode: &str,
    m: Matching,
) -> bool {
    let changed = session.matching.as_ref() != Some(&m);
    if session.matching.is_none() {
        log_info!(
            "round {}: initial pairing via {} ({mode} mode) — {} pair(s), {} solo",
            ev.round,
            cfg.pairing,
            m.pairs.len(),
            m.solos.len()
        );
    } else if changed {
        log_info!(
            "round {}: {mode} re-pair — {} pair(s), {} solo",
            ev.round,
            m.pairs.len(),
            m.solos.len()
        );
    }
    session.matching = Some(m);
    changed
}

/// Create or incrementally repair the FedPairing matching for this round.
///
/// * First call (`matching` is `None`): full pairing of the alive set via the
///   configured strategy. When the configured backend resolves sparse for the
///   fleet size, candidates come straight from the dynamics' incrementally
///   maintained [`SpatialGrid`](crate::sim::geometry::SpatialGrid) — no
///   O(n²) edges, no fleet compaction.
/// * Later rounds: a no-op unless this round saw departures or joins; then
///   only the affected clients are re-matched on *fresh* channel weights,
///   with the repair logged at INFO. Pools past [`DENSE_POOL_MAX`] are
///   re-matched against grid-local candidates only.
///
/// Returns `true` when the matching changed.
///
/// `cost` is the optional split-cost model: when present, Greedy/Exact
/// pairing (initial *and* repairs) optimizes the planner's predicted pair
/// latency instead of the eq. (5) proxy — the pairing/splitting co-design
/// of DESIGN.md §7.
///
/// This is the repair-mode step with a throwaway memo (a fresh memo never
/// hits), so behavior is bit-identical to the historical function
/// regardless of `cfg.pairing_mode`. Mode-aware drivers own a
/// [`PairingSession`] and call [`maintain_matching_session`] instead.
pub fn maintain_matching(
    matching: &mut Option<Matching>,
    dynamics: &FleetDynamics,
    ev: &RoundEvents,
    channel: &Channel,
    cfg: &ExperimentConfig,
    cost: Option<&SplitCostModel>,
    pairing_rng: &mut Rng,
) -> bool {
    repair_step(
        matching,
        &mut RepairMemo::default(),
        dynamics,
        ev,
        channel,
        cfg,
        cost,
        pairing_rng,
    )
}

/// The repair-mode round step: initial pairing via the configured strategy,
/// then churn-pool repair through the cross-round memo.
#[allow(clippy::too_many_arguments)]
fn repair_step(
    matching: &mut Option<Matching>,
    memo: &mut RepairMemo,
    dynamics: &FleetDynamics,
    ev: &RoundEvents,
    channel: &Channel,
    cfg: &ExperimentConfig,
    cost: Option<&SplitCostModel>,
    pairing_rng: &mut Rng,
) -> bool {
    let alive = dynamics.alive_indices();
    let sparse = cfg.backend.sparse_for(alive.len());
    let spec = EdgeWeightSpec::for_strategy_with(cfg.pairing, cfg.alpha, cfg.beta, cost);
    match matching {
        None => {
            let m = match spec {
                Some(spec) if sparse => {
                    // Universe-id pairing straight off the dynamics grid.
                    let g = SparseCandidateGraph::over_members(
                        dynamics.universe(),
                        channel,
                        dynamics.grid(),
                        &alive,
                        spec,
                        cfg.backend.k_near,
                        cfg.backend.k_freq,
                    );
                    match_candidates(&g, &alive)
                }
                None if sparse => {
                    // Random at fleet scale: shuffle the alive ids directly.
                    let mut ids = alive.clone();
                    pairing_rng.shuffle(&mut ids);
                    let mut chunks = ids.chunks_exact(2);
                    let mut pairs = Vec::with_capacity(alive.len() / 2);
                    for c in chunks.by_ref() {
                        pairs.push((c[0], c[1]));
                    }
                    Matching {
                        pairs,
                        solos: chunks.remainder().to_vec(),
                    }
                }
                _ => pair_members_with(
                    &cfg.backend,
                    cfg.pairing,
                    dynamics.universe(),
                    channel,
                    cfg.alpha,
                    cfg.beta,
                    cost,
                    pairing_rng,
                    &alive,
                ),
            };
            log_info!(
                "round {}: initial pairing via {} ({} backend) — {} pair(s), {} solo",
                ev.round,
                cfg.pairing,
                if sparse { "sparse" } else { "dense" },
                m.pairs.len(),
                m.solos.len()
            );
            *matching = Some(m);
            true
        }
        Some(m) => {
            if ev.departed.is_empty() && ev.joined.is_empty() {
                return false;
            }
            let uni = dynamics.universe();
            // Repair with the *configured* mechanism's objective — repairing
            // a random/location/compute baseline with eq. (5) weights would
            // drift its matching toward the FedPairing criterion over churn.
            // All objective formulas live in EdgeWeightSpec::weight; only
            // Random needs its own deterministic per-round pseudo-weight.
            let nonce = pairing_rng.next_u64();
            // Weight fingerprint for the pool memo: the channel-config bits
            // (the per-round shadowing fade is folded into `ref_gain`), the
            // round number whenever a scenario process moves positions or
            // frequencies between rounds (mobility, stragglers), and
            // Random's per-repair nonce. An identical stamp over an
            // identical pool replays identical weights, so the cached pool
            // matching is exact — repeated flap churn under a stable
            // channel repairs for free.
            let c = channel.config();
            let mut stamp = 0u64;
            for bits in [
                c.bandwidth_hz.to_bits(),
                c.tx_power_w.to_bits(),
                c.noise_w.to_bits(),
                c.ref_gain.to_bits(),
                c.ref_dist_m.to_bits(),
                c.pathloss_exp.to_bits(),
            ] {
                stamp ^= bits;
                splitmix64(&mut stamp);
            }
            if cfg.scenario.mobility_m > 0.0 || cfg.scenario.p_straggle > 0.0 {
                stamp ^= ev.round as u64;
                splitmix64(&mut stamp);
            }
            if spec.is_none() {
                stamp ^= nonce;
                splitmix64(&mut stamp);
            }
            let weight: Box<dyn Fn(usize, usize) -> f64 + '_> = match spec {
                Some(spec) => Box::new(move |a, b| spec.weight(uni, channel, a, b)),
                None => Box::new(move |a, b| {
                    let mut s = nonce ^ ((a as u64) << 32) ^ b as u64;
                    splitmix64(&mut s) as f64
                }),
            };
            let rep = repair_matching_pooled_memo(m, &alive, stamp, memo, |pool| match spec {
                // Metro-scale pool: grid-local candidates within the pool
                // only, weights evaluated lazily — never O(pool²).
                Some(spec) if sparse && pool.len() > DENSE_POOL_MAX => {
                    let g = SparseCandidateGraph::over_pool(
                        uni,
                        channel,
                        pool,
                        spec,
                        cfg.backend.k_near,
                        cfg.backend.k_freq,
                    );
                    match_candidates(&g, pool)
                }
                // Random at fleet scale (e.g. a flash cohort joining a
                // metro run): a nonce-seeded shuffle of the pool, matching
                // the initial-pairing path — never O(pool²) edges.
                None if sparse && pool.len() > DENSE_POOL_MAX => {
                    let mut ids = pool.to_vec();
                    Rng::new(nonce).shuffle(&mut ids);
                    let mut chunks = ids.chunks_exact(2);
                    let mut pairs = Vec::with_capacity(pool.len() / 2);
                    for c in chunks.by_ref() {
                        pairs.push((c[0], c[1]));
                    }
                    Matching {
                        pairs,
                        solos: chunks.remainder().to_vec(),
                    }
                }
                _ => dense_pool_matching(pool, &|a, b| weight(a, b)),
            });
            if rep.changed() {
                log_info!(
                    "round {}: incremental re-pair — dropped {}, formed {}, solo {} \
                     ({} pair(s) untouched)",
                    ev.round,
                    rep.dropped_pairs.len(),
                    rep.new_pairs.len(),
                    rep.new_solos.len(),
                    rep.kept_pairs
                );
            }
            rep.changed()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ScenarioConfig, ScenarioKind};
    use crate::sim::latency::Fleet;

    #[test]
    fn maintain_matching_sparse_backend_and_big_pool_repair() {
        // n=300 > AUTO_DENSE_MAX resolves sparse under Auto; violent churn
        // (40 %/round departures) pushes the repair pool past DENSE_POOL_MAX,
        // exercising the grid-local pool matcher.
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 300;
        cfg.samples_per_client = 64;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        cfg.scenario.p_depart = 0.4;
        cfg.scenario.p_rejoin = 0.5;
        // Pin sparse so heavy churn shrinking the fleet below AUTO_DENSE_MAX
        // can't silently fall back to the dense path mid-test.
        cfg.backend.mode = crate::config::BackendMode::Sparse;
        assert!(cfg.backend.sparse_for(cfg.n_clients));
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut dynamics = FleetDynamics::new(&cfg, base);
        let mut rng = Rng::new(5);
        let mut matching = None;
        let mut repaired = 0;
        for round in 1..=8 {
            let ev = dynamics.step(round);
            let ch = dynamics.channel();
            let had = matching.is_some();
            if maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, None, &mut rng) && had {
                repaired += 1;
            }
            let m = matching.as_ref().unwrap();
            assert!(
                m.is_valid_over(&dynamics.alive_indices()),
                "round {round}: invalid after sparse maintain"
            );
        }
        assert!(repaired > 0, "churn never triggered a repair");
    }

    #[test]
    fn maintain_matching_random_big_pool_repair_stays_sparse() {
        // Random strategy with a giant churn pool must take the nonce-seeded
        // shuffle arm — the dense O(pool²) matcher on a metro-scale pool is
        // the scale bug this guards against.
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 300;
        cfg.samples_per_client = 64;
        cfg.pairing = crate::config::PairingStrategy::Random;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        cfg.scenario.p_depart = 0.4;
        cfg.scenario.p_rejoin = 0.5;
        cfg.backend.mode = crate::config::BackendMode::Sparse;
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut dynamics = FleetDynamics::new(&cfg, base);
        let mut rng = Rng::new(7);
        let mut matching = None;
        for round in 1..=6 {
            let ev = dynamics.step(round);
            let ch = dynamics.channel();
            maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, None, &mut rng);
            let m = matching.as_ref().unwrap();
            assert!(
                m.is_valid_over(&dynamics.alive_indices()),
                "round {round}: {m:?}"
            );
        }
    }

    #[test]
    fn session_repair_replays_legacy_maintain() {
        // The session's repair arm (with its live cross-round memo) must be
        // bit-identical to the historical memo-free function — a memo hit
        // that changed the result would be a correctness bug, not a cache.
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 60;
        cfg.samples_per_client = 64;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::FlashCrowd);
        cfg.scenario.p_depart = 0.2;
        cfg.scenario.p_rejoin = 0.4;
        let mut d1 = FleetDynamics::new(&cfg, Fleet::sample(&cfg, &mut Rng::new(cfg.seed)));
        let mut d2 = FleetDynamics::new(&cfg, Fleet::sample(&cfg, &mut Rng::new(cfg.seed)));
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let mut legacy: Option<Matching> = None;
        let mut session = PairingSession::new();
        for round in 1..=25 {
            let e1 = d1.step(round);
            let e2 = d2.step(round);
            assert_eq!(e1, e2);
            let ch1 = d1.channel();
            let ch2 = d2.channel();
            let c1 = maintain_matching(&mut legacy, &d1, &e1, &ch1, &cfg, None, &mut r1);
            let c2 =
                maintain_matching_session(&mut session, &d2, &e2, &ch2, &cfg, None, &mut r2);
            assert_eq!(c1, c2, "round {round}: changed flags diverge");
            assert_eq!(legacy, session.matching, "round {round}: matchings diverge");
        }
    }

    #[test]
    fn incremental_mode_matches_rebuild_mode() {
        // The headline contract: the persistent matcher's output is
        // bit-for-bit the full rebuild's, across churn + mobility + fading.
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 120;
        cfg.samples_per_client = 64;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        cfg.scenario.p_depart = 0.25;
        cfg.scenario.p_rejoin = 0.4;
        cfg.scenario.mobility_m = 4.0;
        let mut reb_cfg = cfg.clone();
        reb_cfg.pairing_mode = PairingMode::Rebuild;
        let mut inc_cfg = cfg.clone();
        inc_cfg.pairing_mode = PairingMode::Incremental;
        let mut d1 = FleetDynamics::new(&cfg, Fleet::sample(&cfg, &mut Rng::new(cfg.seed)));
        let mut d2 = FleetDynamics::new(&cfg, Fleet::sample(&cfg, &mut Rng::new(cfg.seed)));
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let mut s1 = PairingSession::new();
        let mut s2 = PairingSession::new();
        for round in 1..=15 {
            let e1 = d1.step(round);
            let e2 = d2.step(round);
            assert_eq!(e1, e2);
            let ch1 = d1.channel();
            let ch2 = d2.channel();
            let c1 = maintain_matching_session(&mut s1, &d1, &e1, &ch1, &reb_cfg, None, &mut r1);
            let c2 = maintain_matching_session(&mut s2, &d2, &e2, &ch2, &inc_cfg, None, &mut r2);
            assert_eq!(c1, c2, "round {round}: changed flags diverge");
            assert_eq!(s1.matching, s2.matching, "round {round}: matchings diverge");
            let m = s2.matching.as_ref().unwrap();
            assert!(m.is_valid_over(&d2.alive_indices()), "round {round}: {m:?}");
        }
        assert!(s2.matcher_solves() > 0, "matcher never solved");
    }

    #[test]
    fn maintain_matching_initial_then_repair() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 8;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut dynamics = FleetDynamics::new(&cfg, base);
        let mut rng = Rng::new(1);
        let mut matching = None;
        let ev = dynamics.step(1);
        let ch = dynamics.channel();
        assert!(maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, None, &mut rng));
        let m = matching.as_ref().unwrap();
        assert!(m.is_valid_over(&dynamics.alive_indices()), "{m:?}");
        // Step until churn hits, then the matching must stay valid.
        for round in 2..=40 {
            let ev = dynamics.step(round);
            let ch = dynamics.channel();
            maintain_matching(&mut matching, &dynamics, &ev, &ch, &cfg, None, &mut rng);
            let m = matching.as_ref().unwrap();
            assert!(
                m.is_valid_over(&dynamics.alive_indices()),
                "round {round}: {m:?}"
            );
        }
    }
}
