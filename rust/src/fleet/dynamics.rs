//! The per-round fleet process: churn, transient failures, stragglers,
//! mobility and channel shadowing.
//!
//! [`FleetDynamics`] owns a *universe* fleet — the initially-active clients
//! plus any latent flash-crowd cohort — and evolves four pieces of state each
//! round: which clients are **alive** (joined and not departed), which are
//! **present** (alive and not transiently failed/asleep), each client's
//! effective CPU frequency (straggler injection), and the channel state
//! (client positions drift; a global log-normal shadowing factor re-draws).
//!
//! Every draw comes from one dedicated PCG stream derived from
//! `(seed, 0xF1EE7D11A)`, consumed in a deterministic order, so two
//! `FleetDynamics` built from the same config produce bit-identical
//! [`RoundEvents`] traces — a property the integration tests rely on.

use crate::config::{ChannelConfig, ExperimentConfig, ScenarioConfig};
use crate::sim::channel::Channel;
use crate::sim::compute::sample_frequencies;
use crate::sim::geometry::{place_uniform_disk, SpatialGrid};
use crate::sim::latency::Fleet;
use crate::telemetry::registry::{Counter, Gauge};
use crate::util::bitset::BitSet;
use crate::util::rng::Rng;

/// Stream-id salt for all fleet-dynamics randomness.
const FLEET_STREAM_SALT: u64 = 0xF1EE7_D11A;

/// Everything that happened to the fleet in one round.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundEvents {
    pub round: usize,
    /// Clients that (re)joined this round (flash cohort or rejoiners).
    pub joined: Vec<usize>,
    /// Clients that durably departed this round.
    pub departed: Vec<usize>,
    /// Alive clients that miss this round (transient failure / asleep).
    pub transient_out: Vec<usize>,
    /// Present clients running at `straggle_factor × f_i` this round.
    pub stragglers: Vec<usize>,
    /// This round's global shadowing draw in dB (0 when disabled).
    pub shadowing_db: f64,
    /// Number of clients actually participating this round.
    pub n_alive: usize,
}

/// Total number of potential clients (initial fleet + latent flash cohort).
pub fn universe_size(cfg: &ExperimentConfig) -> usize {
    let sc = &cfg.scenario;
    let extra = if sc.flash_round > 0 {
        (cfg.n_clients as f64 * sc.flash_fraction).ceil() as usize
    } else {
        0
    };
    cfg.n_clients + extra
}

/// The evolving fleet (see module docs).
#[derive(Clone, Debug)]
pub struct FleetDynamics {
    scenario: ScenarioConfig,
    channel_cfg: ChannelConfig,
    area_radius_m: f64,
    /// Universe fleet; positions and freqs mutate round to round.
    universe: Fleet,
    /// Unslowed CPU frequencies (straggling is transient).
    base_freqs: Vec<f64>,
    /// Membership flags as packed bit sets (memory diet: 1 bit per client
    /// per flag instead of a byte — reads keep the `flags[c]` shape via
    /// `Index`, mutation goes through `.set()`).
    alive: BitSet,
    present: BitSet,
    /// Universe ids participating in the current round (ascending) — the
    /// materialized form of `present`, rebuilt in place each [`Self::step`]
    /// so per-round views borrow instead of re-collecting.
    present_ids: Vec<usize>,
    /// Universe ids currently alive (ascending) — materialized form of
    /// `alive`, rebuilt each [`Self::step`].
    alive_ids: Vec<usize>,
    /// Flash-crowd cohort members that have not joined yet.
    latent: BitSet,
    rng: Rng,
    /// Current global shadowing factor in dB.
    fade_db: f64,
    /// Spatial index over the *alive* clients, maintained incrementally:
    /// O(1) membership updates on join/depart and O(1) relocations as
    /// clients drift — never rebuilt from global state. The sparse pairing
    /// backend reads it directly (ids are universe ids).
    grid: SpatialGrid,
}

impl FleetDynamics {
    /// Build from an already-sampled base fleet (so the `stable` scenario
    /// reuses the exact fleet the static path would). The latent flash
    /// cohort, if any, is sampled here from a dedicated stream.
    pub fn new(cfg: &ExperimentConfig, base: Fleet) -> FleetDynamics {
        assert_eq!(
            base.n(),
            cfg.n_clients,
            "base fleet size must equal n_clients"
        );
        let total = universe_size(cfg);
        let extra = total - cfg.n_clients;
        let mut universe = base;
        if extra > 0 {
            let mut cohort_rng = Rng::with_stream(cfg.seed ^ FLEET_STREAM_SALT, 1);
            universe
                .positions
                .extend(place_uniform_disk(&mut cohort_rng, extra, cfg.area_radius_m));
            universe
                .freqs_hz
                .extend(sample_frequencies(&mut cohort_rng, extra, &cfg.compute));
            universe
                .n_samples
                .extend(std::iter::repeat(cfg.samples_per_client).take(extra));
        }
        Self::from_universe(cfg, universe)
    }

    /// Build from an already-materialized universe fleet (base clients +
    /// latent cohort, in that order). Lets a caller sample the universe
    /// once, keep it, and construct fresh dynamics from it per run without
    /// relying on two constructions sampling identically.
    pub fn from_universe(cfg: &ExperimentConfig, universe: Fleet) -> FleetDynamics {
        assert_eq!(
            universe.n(),
            universe_size(cfg),
            "universe fleet size must equal universe_size(cfg)"
        );
        let n = universe.n();
        let alive = BitSet::from_ids(n, 0..cfg.n_clients);
        let latent = BitSet::from_ids(n, cfg.n_clients..n);
        let mut grid = SpatialGrid::new(cfg.area_radius_m, n);
        for c in 0..cfg.n_clients {
            grid.insert(c, universe.positions[c]);
        }
        FleetDynamics {
            scenario: cfg.scenario,
            channel_cfg: cfg.channel,
            area_radius_m: cfg.area_radius_m,
            base_freqs: universe.freqs_hz.clone(),
            present: alive.clone(),
            present_ids: (0..cfg.n_clients).collect(),
            alive_ids: (0..cfg.n_clients).collect(),
            universe,
            alive,
            latent,
            rng: Rng::with_stream(cfg.seed ^ FLEET_STREAM_SALT, 2),
            fade_db: 0.0,
            grid,
        }
    }

    /// Advance the fleet to `round` (1-based, called once per round in
    /// order) and report what changed.
    pub fn step(&mut self, round: usize) -> RoundEvents {
        let sc = self.scenario;
        let n = self.universe.n();
        let mut ev = RoundEvents {
            round,
            joined: Vec::new(),
            departed: Vec::new(),
            transient_out: Vec::new(),
            stragglers: Vec::new(),
            shadowing_db: 0.0,
            n_alive: 0,
        };
        // 1. Flash-crowd cohort joins all at once.
        if sc.flash_round > 0 && round == sc.flash_round {
            for c in 0..n {
                if self.latent[c] {
                    self.latent.remove(c);
                    self.alive.insert(c);
                    self.grid.insert(c, self.universe.positions[c]);
                    ev.joined.push(c);
                }
            }
        }
        // 2. Departed clients may rejoin.
        if sc.p_rejoin > 0.0 {
            for c in 0..n {
                if !self.alive[c] && !self.latent[c] && self.rng.f64() < sc.p_rejoin {
                    self.alive.insert(c);
                    self.grid.insert(c, self.universe.positions[c]);
                    ev.joined.push(c);
                }
            }
        }
        // 3. Durable departures (the fleet never empties entirely).
        if sc.p_depart > 0.0 {
            let mut alive_count = self.alive.count();
            for c in 0..n {
                if self.alive[c] && alive_count > 1 && self.rng.f64() < sc.p_depart {
                    self.alive.remove(c);
                    self.grid.remove(c);
                    alive_count -= 1;
                    ev.departed.push(c);
                }
            }
        }
        // 4. Transient failures + the diurnal availability wave.
        let p_sleep = if sc.diurnal_period > 0 {
            let phase = 2.0 * std::f64::consts::PI * round as f64 / sc.diurnal_period as f64;
            sc.diurnal_depth * 0.5 * (1.0 - phase.cos())
        } else {
            0.0
        };
        let p_out = (sc.p_transient + p_sleep).min(1.0);
        for c in 0..n {
            let mut p = self.alive[c];
            if p && p_out > 0.0 && self.rng.f64() < p_out {
                p = false;
                ev.transient_out.push(c);
            }
            self.present.set(c, p);
        }
        // Guard: a round always has at least one participant.
        if self.present.is_clear() {
            if let Some(first) = self.alive.iter().next() {
                self.present.insert(first);
                ev.transient_out.retain(|&c| c != first);
            }
        }
        // 5. Straggler injection (freqs reset to base for everyone else).
        for c in 0..n {
            let mut f = self.base_freqs[c];
            if self.present[c] && sc.p_straggle > 0.0 && self.rng.f64() < sc.p_straggle {
                f *= sc.straggle_factor;
                ev.stragglers.push(c);
            }
            self.universe.freqs_hz[c] = f;
        }
        // 6. Mobility: alive clients random-walk inside the disk; the
        //    spatial index follows each move (cell-change only — an O(1)
        //    no-op for small drift).
        if sc.mobility_m > 0.0 {
            let mut relocated = 0u64;
            for c in 0..n {
                if self.alive[c] {
                    let dx = self.rng.normal_ms(0.0, sc.mobility_m);
                    let dy = self.rng.normal_ms(0.0, sc.mobility_m);
                    let p = &mut self.universe.positions[c];
                    p.x += dx;
                    p.y += dy;
                    let d = p.dist_to_server();
                    if d > self.area_radius_m {
                        let s = self.area_radius_m / d;
                        p.x *= s;
                        p.y *= s;
                    }
                    let moved = *p;
                    self.grid.relocate(c, moved);
                    relocated += 1;
                }
            }
            crate::tm_count!(Counter::GridRelocations, relocated);
        }
        // 7. Channel shadowing re-draw (block fading: one draw per round).
        self.fade_db = if sc.shadowing_std_db > 0.0 {
            self.rng.normal_ms(0.0, sc.shadowing_std_db)
        } else {
            0.0
        };
        ev.shadowing_db = self.fade_db;
        // 8. Materialize this round's participant and alive lists in place
        //    (no per-round allocation after warmup).
        self.present_ids.clear();
        self.present_ids.extend(self.present.iter());
        self.alive_ids.clear();
        self.alive_ids.extend(self.alive.iter());
        ev.n_alive = self.present_ids.len();
        crate::tm_gauge!(Gauge::FleetAlive, ev.n_alive as u64);
        ev
    }

    /// The full universe fleet in its *current* state (positions and
    /// straggle-adjusted frequencies as of the last `step`).
    pub fn universe(&self) -> &Fleet {
        &self.universe
    }

    /// Universe ids of clients currently alive (matching membership).
    pub fn alive_indices(&self) -> Vec<usize> {
        self.alive_ids.clone()
    }

    /// Borrowed form of [`Self::alive_indices`] (ascending; rebuilt each
    /// [`Self::step`]) — the zero-allocation input to matching maintenance.
    pub fn alive_members(&self) -> &[usize] {
        &self.alive_ids
    }

    /// Packed membership bits of the alive set (capacity = universe size).
    pub fn alive_set(&self) -> &BitSet {
        &self.alive
    }

    /// The incrementally-maintained spatial index over the alive clients
    /// (universe ids). The sparse pairing backend builds its candidate lists
    /// from this grid instead of scanning the fleet.
    pub fn grid(&self) -> &SpatialGrid {
        &self.grid
    }

    /// Universe ids participating in the current round (ascending), borrowed
    /// from the per-round scratch — the zero-allocation input to
    /// [`crate::sim::latency::FleetView`].
    pub fn present_members(&self) -> &[usize] {
        &self.present_ids
    }

    /// Universe ids participating in the current round (owned copy; prefer
    /// [`Self::present_members`] on the hot path).
    pub fn present_indices(&self) -> Vec<usize> {
        self.present_ids.clone()
    }

    /// Compact fleet of this round's participants plus the compact→universe
    /// id map (ascending, so `members.binary_search(&u)` inverts it).
    /// Allocating variant — the drivers use a borrowed
    /// [`crate::sim::latency::FleetView`] over [`Self::present_members`]
    /// instead.
    pub fn present_view(&self) -> (Fleet, Vec<usize>) {
        let members = self.present_indices();
        (self.universe.subset(&members), members)
    }

    /// This round's channel: the configured eq. (3) model with the current
    /// shadowing draw folded into the reference gain.
    pub fn channel(&self) -> Channel {
        let mut cfg = self.channel_cfg;
        cfg.ref_gain *= 10f64.powf(self.fade_db / 10.0);
        Channel::new(cfg)
    }

    pub fn scenario(&self) -> &ScenarioConfig {
        &self.scenario
    }

    /// Run the full churn trace for a config without training anything —
    /// the determinism contract's test surface.
    pub fn trace(cfg: &ExperimentConfig) -> Vec<RoundEvents> {
        let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(cfg, base);
        (1..=cfg.rounds).map(|r| d.step(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ScenarioConfig, ScenarioKind};

    fn cfg_with(kind: ScenarioKind, n: usize, rounds: usize, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        cfg.rounds = rounds;
        cfg.seed = seed;
        cfg.scenario = ScenarioConfig::preset(kind);
        cfg
    }

    #[test]
    fn stable_scenario_is_a_true_noop() {
        let cfg = cfg_with(ScenarioKind::Stable, 10, 5, 3);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let positions = base.positions.clone();
        let freqs = base.freqs_hz.clone();
        let mut d = FleetDynamics::new(&cfg, base);
        for round in 1..=5 {
            let ev = d.step(round);
            assert!(ev.joined.is_empty() && ev.departed.is_empty());
            assert!(ev.transient_out.is_empty() && ev.stragglers.is_empty());
            assert_eq!(ev.shadowing_db, 0.0);
            assert_eq!(ev.n_alive, 10);
        }
        // Fleet state untouched, channel identical to the static one.
        assert_eq!(d.universe().positions, positions);
        assert_eq!(d.universe().freqs_hz, freqs);
        let ch = d.channel();
        assert_eq!(ch.config().ref_gain, cfg.channel.ref_gain);
    }

    #[test]
    fn traces_are_bit_identical_for_same_seed_and_scenario() {
        for kind in ScenarioKind::ALL {
            let cfg = cfg_with(kind, 12, 30, 77);
            let a = FleetDynamics::trace(&cfg);
            let b = FleetDynamics::trace(&cfg);
            assert_eq!(a, b, "{kind:?} trace not deterministic");
        }
    }

    #[test]
    fn different_seeds_give_different_churn() {
        let a = FleetDynamics::trace(&cfg_with(ScenarioKind::LossyRadio, 12, 30, 1));
        let b = FleetDynamics::trace(&cfg_with(ScenarioKind::LossyRadio, 12, 30, 2));
        assert_ne!(a, b);
    }

    #[test]
    fn flash_crowd_cohort_joins_at_flash_round() {
        let cfg = cfg_with(ScenarioKind::FlashCrowd, 10, 10, 5);
        assert_eq!(universe_size(&cfg), 15); // +50 %
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        assert_eq!(d.universe().n(), 15);
        assert_eq!(d.alive_indices().len(), 10);
        let mut saw_flash = false;
        for round in 1..=10 {
            let ev = d.step(round);
            if round == cfg.scenario.flash_round {
                // All five latent clients join at once (ids 10..15).
                assert!(ev.joined.iter().filter(|&&c| c >= 10).count() == 5, "{ev:?}");
                saw_flash = true;
            }
        }
        assert!(saw_flash);
    }

    #[test]
    fn lossy_radio_churns_and_fades() {
        let cfg = cfg_with(ScenarioKind::LossyRadio, 14, 40, 9);
        let trace = FleetDynamics::trace(&cfg);
        let departures: usize = trace.iter().map(|e| e.departed.len()).sum();
        let stragglers: usize = trace.iter().map(|e| e.stragglers.len()).sum();
        let transients: usize = trace.iter().map(|e| e.transient_out.len()).sum();
        assert!(departures > 0, "no departures over 40 lossy rounds");
        assert!(stragglers > 0);
        assert!(transients > 0);
        assert!(trace.iter().any(|e| e.shadowing_db != 0.0));
        // Alive counts recorded every round and never zero.
        assert!(trace.iter().all(|e| e.n_alive >= 1));
        // Churn actually moves the participation level around.
        let min = trace.iter().map(|e| e.n_alive).min().unwrap();
        let max = trace.iter().map(|e| e.n_alive).max().unwrap();
        assert!(min < max, "alive count never varied: {min}");
    }

    #[test]
    fn diurnal_wave_dips_availability() {
        let cfg = cfg_with(ScenarioKind::Diurnal, 20, 20, 21);
        let trace = FleetDynamics::trace(&cfg);
        // Near the trough (round = period/2 = 10) more clients sleep than
        // near the crest (round = period = 20).
        let trough: usize = trace[8..12].iter().map(|e| e.transient_out.len()).sum();
        let crest = trace[19].transient_out.len() + trace[0].transient_out.len();
        assert!(trough > crest, "trough {trough} !> crest {crest}");
    }

    #[test]
    fn mobility_stays_inside_the_disk() {
        let mut cfg = cfg_with(ScenarioKind::LossyRadio, 10, 50, 13);
        cfg.scenario.mobility_m = 10.0; // violent drift
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        for round in 1..=50 {
            d.step(round);
            for p in &d.universe().positions {
                assert!(p.dist_to_server() <= cfg.area_radius_m + 1e-9);
            }
        }
    }

    #[test]
    fn never_departs_below_one_alive() {
        let mut cfg = cfg_with(ScenarioKind::LossyRadio, 3, 200, 17);
        cfg.scenario.p_depart = 0.9;
        cfg.scenario.p_rejoin = 0.0;
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        for round in 1..=200 {
            let ev = d.step(round);
            assert!(!d.alive_indices().is_empty());
            assert!(ev.n_alive >= 1);
        }
    }

    #[test]
    fn grid_tracks_alive_set_incrementally() {
        // Heavy churn + mobility: after every step the incrementally-updated
        // grid must hold exactly the alive clients, each in the cell a fresh
        // rebuild would put it in.
        let cfg = cfg_with(ScenarioKind::LossyRadio, 16, 40, 31);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        for round in 1..=40 {
            d.step(round);
            assert_eq!(d.grid().members(), d.alive_indices(), "round {round}");
            for &c in &d.alive_indices() {
                let p = d.universe().positions[c];
                let mut found = false;
                let (cx, cy) = d.grid().cell_xy(&p);
                d.grid().for_ring(cx, cy, 0, |cell| found = cell.contains(&(c as u32)));
                assert!(found, "round {round}: client {c} not in its cell");
            }
        }
    }

    #[test]
    fn flash_crowd_joiners_enter_the_grid() {
        let cfg = cfg_with(ScenarioKind::FlashCrowd, 10, 10, 33);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        assert_eq!(d.grid().len(), 10);
        for round in 1..=cfg.scenario.flash_round {
            d.step(round);
        }
        // All five latent clients (ids 10..15) are now indexed.
        assert!(d.grid().len() >= 10, "cohort missing from grid");
        assert_eq!(d.grid().members(), d.alive_indices());
    }

    #[test]
    fn present_members_tracks_the_present_flags() {
        // The zero-allocation member slice must equal the flag-derived list
        // after every step, and n_alive must equal its length.
        let cfg = cfg_with(ScenarioKind::LossyRadio, 12, 30, 41);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        assert_eq!(d.present_members(), &(0..12).collect::<Vec<_>>()[..]);
        for round in 1..=30 {
            let ev = d.step(round);
            let expect: Vec<usize> = (0..d.universe().n()).filter(|&c| d.present[c]).collect();
            assert_eq!(d.present_members(), &expect[..], "round {round}");
            assert_eq!(ev.n_alive, expect.len());
            // The allocating variants agree with the borrowed slice.
            let (sub, members) = d.present_view();
            assert_eq!(members, d.present_members());
            assert_eq!(sub.n(), members.len());
        }
    }

    #[test]
    fn shadowing_moves_the_channel() {
        let cfg = cfg_with(ScenarioKind::LossyRadio, 8, 10, 23);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d = FleetDynamics::new(&cfg, base);
        let mut gains = Vec::new();
        for round in 1..=10 {
            d.step(round);
            gains.push(d.channel().config().ref_gain);
        }
        gains.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(gains[0] < gains[9], "shadowing never changed the gain");
    }
}
