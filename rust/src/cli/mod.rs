//! Command-line parsing (substrate — no `clap` in this environment).
//!
//! Supports subcommands, long/short flags, `--key value` and `--key=value`,
//! repeated flags, typed extraction with defaults, and auto-generated
//! `--help`. Deliberately small: exactly what the `fedpairing` binary,
//! examples and benches need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared flag (for help text + validation).
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub short: Option<char>,
    pub value_name: Option<&'static str>, // None => boolean switch
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// A declarative CLI: name, about, flags, positional args, subcommands.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
    pub positionals: Vec<(&'static str, &'static str)>, // (name, help)
    pub subcommands: Vec<Command>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            ..Default::default()
        }
    }

    pub fn flag(
        mut self,
        name: &'static str,
        short: Option<char>,
        value_name: Option<&'static str>,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.flags.push(FlagSpec {
            name,
            short,
            value_name,
            help,
            default,
        });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subcommands.push(sub);
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = write!(s, "USAGE: {}", self.name);
        if !self.subcommands.is_empty() {
            let _ = write!(s, " <SUBCOMMAND>");
        }
        if !self.flags.is_empty() {
            let _ = write!(s, " [FLAGS]");
        }
        for (p, _) in &self.positionals {
            let _ = write!(s, " <{p}>");
        }
        let _ = writeln!(s);
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\nARGS:");
            for (p, h) in &self.positionals {
                let _ = writeln!(s, "  <{p}>  {h}");
            }
        }
        if !self.flags.is_empty() {
            let _ = writeln!(s, "\nFLAGS:");
            for f in &self.flags {
                let short = f.short.map(|c| format!("-{c}, ")).unwrap_or_default();
                let val = f.value_name.map(|v| format!(" <{v}>")).unwrap_or_default();
                let def = f
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                let _ = writeln!(s, "  {short}--{}{val}  {}{def}", f.name, f.help);
            }
        }
        if !self.subcommands.is_empty() {
            let _ = writeln!(s, "\nSUBCOMMANDS:");
            for sub in &self.subcommands {
                let _ = writeln!(s, "  {:<18} {}", sub.name, sub.about);
            }
        }
        s
    }

    /// Parse `args` (exclusive of argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut parsed = Parsed {
            command_path: vec![self.name.to_string()],
            ..Default::default()
        };
        // Seed defaults.
        for f in &self.flags {
            if let (Some(d), Some(_)) = (f.default, f.value_name) {
                parsed.values.insert(f.name.to_string(), vec![d.to_string()]);
            }
        }
        self.parse_into(args, &mut parsed)?;
        Ok(parsed)
    }

    fn find_flag(&self, token: &str) -> Option<&FlagSpec> {
        if let Some(name) = token.strip_prefix("--") {
            let name = name.split('=').next().unwrap();
            self.flags.iter().find(|f| f.name == name)
        } else if let Some(rest) = token.strip_prefix('-') {
            let mut chars = rest.chars();
            let c = chars.next()?;
            if chars.next().is_some() {
                return None; // no combined short flags
            }
            self.flags.iter().find(|f| f.short == Some(c))
        } else {
            None
        }
    }

    fn parse_into(&self, args: &[String], parsed: &mut Parsed) -> Result<(), CliError> {
        let mut i = 0;
        while i < args.len() {
            let tok = &args[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::HelpRequested(self.help()));
            }
            if tok.starts_with('-') && tok != "-" {
                let spec = self.find_flag(tok).ok_or_else(|| {
                    CliError::Unknown(format!("unknown flag {tok} for {}", self.name))
                })?;
                if spec.value_name.is_some() {
                    let value = if let Some(eq) = tok.find('=') {
                        tok[eq + 1..].to_string()
                    } else {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| {
                                CliError::Unknown(format!("flag --{} needs a value", spec.name))
                            })?
                    };
                    parsed
                        .values
                        .entry(spec.name.to_string())
                        .or_default()
                        .push(value);
                    // A provided value overrides the default (keep only the last
                    // non-default unless the flag is repeated by the user).
                    let vals = parsed.values.get_mut(spec.name).unwrap();
                    if vals.len() == 2 && spec.default.map(String::from).as_deref() == Some(&vals[0]) {
                        vals.remove(0);
                    }
                } else {
                    parsed.switches.insert(spec.name.to_string());
                }
            } else if let Some(sub) = self.subcommands.iter().find(|s| s.name == *tok) {
                parsed.command_path.push(sub.name.to_string());
                for f in &sub.flags {
                    if let (Some(d), Some(_)) = (f.default, f.value_name) {
                        parsed
                            .values
                            .entry(f.name.to_string())
                            .or_insert_with(|| vec![d.to_string()]);
                    }
                }
                return sub.parse_into(&args[i + 1..], parsed);
            } else {
                parsed.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(())
    }
}

/// Parse outcome.
#[derive(Debug, Default)]
pub struct Parsed {
    pub command_path: Vec<String>,
    pub values: BTreeMap<String, Vec<String>>,
    pub switches: std::collections::BTreeSet<String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn subcommand(&self) -> Option<&str> {
        self.command_path.get(1).map(|s| s.as_str())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|_| {
                CliError::Unknown(format!("flag --{name}: cannot parse {s:?}"))
            }),
        }
    }

    /// Typed getter with a required default already registered.
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        self.get_parsed::<T>(name)?
            .ok_or_else(|| CliError::Unknown(format!("missing required flag --{name}")))
    }
}

/// CLI failure modes.
#[derive(Debug)]
pub enum CliError {
    HelpRequested(String),
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::HelpRequested(h) => write!(f, "{h}"),
            CliError::Unknown(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("fp", "test tool")
            .flag("clients", Some('n'), Some("N"), "number of clients", Some("20"))
            .flag("verbose", Some('v'), None, "chatty", None)
            .subcommand(
                Command::new("run", "run an experiment")
                    .flag("rounds", Some('r'), Some("N"), "rounds", Some("100"))
                    .flag("algo", None, Some("NAME"), "algorithm", Some("fedpairing"))
                    .positional("config", "config file"),
            )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&argv(&["run"])).unwrap();
        assert_eq!(p.subcommand(), Some("run"));
        assert_eq!(p.req::<usize>("rounds").unwrap(), 100);
        assert_eq!(p.get("algo"), Some("fedpairing"));
        assert_eq!(p.req::<usize>("clients").unwrap(), 20);
    }

    #[test]
    fn overrides_and_equals_syntax() {
        let p = cmd()
            .parse(&argv(&["--clients", "8", "run", "--rounds=5", "cfg.json"]))
            .unwrap();
        assert_eq!(p.req::<usize>("clients").unwrap(), 8);
        assert_eq!(p.req::<usize>("rounds").unwrap(), 5);
        assert_eq!(p.positionals, vec!["cfg.json"]);
    }

    #[test]
    fn short_flags() {
        let p = cmd().parse(&argv(&["-n", "4", "-v", "run", "-r", "7"])).unwrap();
        assert_eq!(p.req::<usize>("clients").unwrap(), 4);
        assert!(p.has("verbose"));
        assert_eq!(p.req::<usize>("rounds").unwrap(), 7);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cmd().parse(&argv(&["--bogus"])).is_err());
    }

    #[test]
    fn help_contains_flags_and_subcommands() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        let CliError::HelpRequested(h) = err else {
            panic!("expected help");
        };
        assert!(h.contains("--clients"));
        assert!(h.contains("run"));
    }

    #[test]
    fn subcommand_help() {
        let err = cmd().parse(&argv(&["run", "--help"])).unwrap_err();
        let CliError::HelpRequested(h) = err else {
            panic!("expected help");
        };
        assert!(h.contains("--rounds"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cmd().parse(&argv(&["--clients"])).is_err());
    }

    #[test]
    fn parse_type_error() {
        let p = cmd().parse(&argv(&["--clients", "abc"])).unwrap();
        assert!(p.req::<usize>("clients").is_err());
    }
}
