//! Mid-round fault injection and recovery properties (DESIGN.md §11).
//!
//! The pinned invariants:
//!
//! 1. **Zero-hazard bit-identity** — an *armed* fault model whose hazards are
//!    all zero and whose deadline never binds must leave the trace
//!    bit-for-bit identical to a fault-free run: same `sim_round_s`,
//!    `sim_total_s`, stage breakdowns, critical paths and (all-zero) fault
//!    counters, at any thread count, for all four algorithms.
//! 2. **Cross-thread reproducibility** — with hazards enabled, a fixed
//!    `(seed, config)` produces the same fault events, retry counts, losses
//!    and round times regardless of `engine.threads`.
//! 3. **Deadline monotonicity** — tightening the server deadline (everything
//!    else fixed) never makes a round slower and never recovers a lost
//!    update: per-round `sim_round_s` is non-increasing and
//!    `n_lost_updates` non-decreasing in the deadline.
//! 4. **Accounting sanity under chaos** — per round, terminal failures and
//!    lost updates are bounded by the participant count and recovery time is
//!    finite and non-negative.
//!
//! Every test serializes on one mutex: the telemetry registry gate is
//! process-wide and `Telemetry::new` (constructed by every scenario run)
//! flips it.

use fedpairing::config::{
    AggregationMode, Algorithm, ExperimentConfig, RoundBackend, ScenarioConfig, ScenarioKind,
};
use fedpairing::coordinator::metrics::RoundRecord;
use fedpairing::fleet::simulate_scenario;
use fedpairing::telemetry::registry::{self, Counter};
use fedpairing::util::json::Json;
use std::sync::Mutex;

/// Process-wide serialization for the global registry gate.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

const N_CLIENTS: usize = 12;
const ROUNDS: usize = 30;

/// A deadline far beyond any round makespan: arms the fault pass without
/// ever binding.
const NEVER_BINDS_S: f64 = 1e30;

fn cfg(kind: ScenarioKind, algo: Algorithm) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = N_CLIENTS;
    c.rounds = ROUNDS;
    c.samples_per_client = 250;
    c.algorithm = algo;
    c.scenario = ScenarioConfig::preset(kind);
    c
}

/// Arm the three stage hazards (crash during compute, pair-link drop,
/// uplink loss) on a copy of `base`.
fn hazards(base: &ExperimentConfig, crash: f64, link: f64, uplink: f64) -> ExperimentConfig {
    let mut c = base.clone();
    c.faults.crash_per_round = crash;
    c.faults.link_drop = link;
    c.faults.uplink_loss = uplink;
    c
}

const ALGOS: [Algorithm; 4] = [
    Algorithm::FedPairing,
    Algorithm::VanillaFL,
    Algorithm::VanillaSL,
    Algorithm::SplitFed,
];

/// Every observable bit of a round record except `staleness_mean` (NaN on
/// sync rows), including the fault counters. NaN-safe: compares bit
/// patterns.
type Fp = (
    (usize, usize, u64, u64, u64, u64),
    ([u64; 7], i64, i64, u64),
    (usize, usize, usize, u64),
);

fn fingerprint(rounds: &[RoundRecord]) -> Vec<Fp> {
    rounds
        .iter()
        .map(|r| {
            (
                (
                    r.round,
                    r.n_alive,
                    r.sim_round_s.to_bits(),
                    r.sim_total_s.to_bits(),
                    r.t_wall_s.to_bits(),
                    r.mean_cut.to_bits(),
                ),
                (
                    r.stages.stage_s.map(f64::to_bits),
                    r.stages.crit_a,
                    r.stages.crit_b,
                    r.stages.crit_slack_s.to_bits(),
                ),
                (
                    r.faults.n_failed,
                    r.faults.n_retries,
                    r.faults.n_lost_updates,
                    r.faults.recovery_s.to_bits(),
                ),
            )
        })
        .collect()
}

#[test]
fn armed_zero_hazard_faults_are_bit_identical_to_fault_free() {
    let _g = lock();
    // An armed model (deadline_s > 0 switches the whole fault pass on) with
    // zero hazards and a deadline that never binds must replay every round
    // to the identical bits — the pass prices the same units the engine
    // already priced and folds them back unchanged.
    for kind in [ScenarioKind::Stable, ScenarioKind::LossyRadio] {
        for algo in ALGOS {
            for threads in [1usize, 4] {
                let mut base = cfg(kind, algo);
                base.engine.threads = threads;
                let mut armed = base.clone();
                armed.faults.deadline_s = NEVER_BINDS_S;
                let a = simulate_scenario(&base).unwrap();
                let b = simulate_scenario(&armed).unwrap();
                assert_eq!(
                    fingerprint(&a.result.rounds),
                    fingerprint(&b.result.rounds),
                    "{kind:?}/{algo:?}/threads={threads}: armed zero-hazard trace diverged"
                );
                assert_eq!(a.trace, b.trace, "{kind:?}/{algo:?}: churn trace diverged");
                for r in &b.result.rounds {
                    assert_eq!(r.faults.n_failed, 0);
                    assert_eq!(r.faults.n_retries, 0);
                    assert_eq!(r.faults.n_lost_updates, 0);
                    assert_eq!(r.faults.recovery_s, 0.0);
                }
            }
        }
    }
}

#[test]
fn fault_traces_are_identical_across_thread_counts() {
    let _g = lock();
    for algo in ALGOS {
        let base = cfg(ScenarioKind::LossyRadio, algo);
        let mut one = hazards(&base, 0.05, 0.08, 0.04);
        one.engine.threads = 1;
        let mut four = one.clone();
        four.engine.threads = 4;
        let a = simulate_scenario(&one).unwrap();
        let b = simulate_scenario(&four).unwrap();
        assert_eq!(
            fingerprint(&a.result.rounds),
            fingerprint(&b.result.rounds),
            "{algo:?}: faulted trace depends on thread count"
        );
        assert_eq!(a.trace, b.trace, "{algo:?}: churn trace diverged");
        // The hazards are high enough that a silent no-op would be a bug.
        let activity: usize = a
            .result
            .rounds
            .iter()
            .map(|r| r.faults.n_failed + r.faults.n_retries + r.faults.n_lost_updates)
            .sum();
        assert!(activity > 0, "{algo:?}: no fault ever fired at 5%/8%/4% hazards");
    }
}

#[test]
fn chaos_accounting_stays_consistent() {
    let _g = lock();
    for algo in [Algorithm::FedPairing, Algorithm::SplitFed] {
        let c = hazards(&cfg(ScenarioKind::LossyRadio, algo), 0.15, 0.2, 0.1);
        let run = simulate_scenario(&c).unwrap();
        assert_eq!(run.result.rounds.len(), ROUNDS);
        let (mut failed, mut retries, mut lost) = (0usize, 0usize, 0usize);
        for r in &run.result.rounds {
            assert!(
                r.faults.n_failed <= r.n_alive,
                "{algo:?} round {}: {} failures among {} participants",
                r.round,
                r.faults.n_failed,
                r.n_alive
            );
            assert!(r.faults.n_lost_updates <= r.n_alive, "{algo:?} round {}", r.round);
            assert!(
                r.faults.recovery_s.is_finite() && r.faults.recovery_s >= 0.0,
                "{algo:?} round {}",
                r.round
            );
            // Retries cost backoff, so recovery time must show up with them.
            if r.faults.n_retries > 0 {
                assert!(r.faults.recovery_s > 0.0, "{algo:?} round {}", r.round);
            }
            assert!(r.sim_round_s.is_finite() && r.sim_round_s > 0.0);
            failed += r.faults.n_failed;
            retries += r.faults.n_retries;
            lost += r.faults.n_lost_updates;
        }
        assert!(failed > 0, "{algo:?}: chaos produced no terminal failures");
        assert!(retries > 0, "{algo:?}: chaos produced no retries");
        assert!(lost > 0, "{algo:?}: chaos lost no updates");
    }
}

#[test]
fn tighter_deadlines_never_slow_rounds_or_recover_updates() {
    let _g = lock();
    let base = cfg(ScenarioKind::Stable, Algorithm::FedPairing);
    // Calibrate the deadline ladder off the fault-free makespan.
    let clean = simulate_scenario(&base).unwrap();
    let rmax = clean
        .result
        .rounds
        .iter()
        .map(|r| r.sim_round_s)
        .fold(0.0f64, f64::max);
    assert!(rmax > 0.0);

    let faulty = hazards(&base, 0.05, 0.1, 0.05);
    // A non-binding deadline must not perturb a hazard-only run.
    let mut never = faulty.clone();
    never.faults.deadline_s = NEVER_BINDS_S;
    let unbounded = simulate_scenario(&faulty).unwrap();
    let armed = simulate_scenario(&never).unwrap();
    assert_eq!(
        fingerprint(&unbounded.result.rounds),
        fingerprint(&armed.result.rounds),
        "a never-binding deadline changed the hazard-only trace"
    );

    let ladder = [NEVER_BINDS_S, rmax, 0.6 * rmax, 0.3 * rmax];
    let runs: Vec<_> = ladder
        .iter()
        .map(|&d| {
            let mut c = faulty.clone();
            c.faults.deadline_s = d;
            simulate_scenario(&c).unwrap()
        })
        .collect();
    for w in runs.windows(2) {
        let (loose, tight) = (&w[0].result.rounds, &w[1].result.rounds);
        assert_eq!(loose.len(), tight.len());
        for (l, t) in loose.iter().zip(tight) {
            assert!(
                t.sim_round_s <= l.sim_round_s,
                "round {}: tightening the deadline slowed the round ({} > {})",
                l.round,
                t.sim_round_s,
                l.sim_round_s
            );
            assert!(
                t.faults.n_lost_updates >= l.faults.n_lost_updates,
                "round {}: tightening the deadline recovered an update",
                l.round
            );
        }
    }
    let cut: usize = runs
        .last()
        .unwrap()
        .result
        .rounds
        .iter()
        .map(|r| r.faults.n_lost_updates)
        .sum();
    assert!(cut > 0, "a deadline at 30% of the makespan never cut anything");
}

#[test]
fn fault_validation_rejects_bad_configs() {
    let _g = lock();
    let base = cfg(ScenarioKind::Stable, Algorithm::FedPairing);

    let mut c = base.clone();
    c.faults.crash_per_round = 1.5;
    assert!(simulate_scenario(&c).is_err(), "hazard > 1 accepted");

    let mut c = base.clone();
    c.faults.crash_per_round = 0.1;
    c.faults.recovery.backoff_jitter = 2.0;
    assert!(simulate_scenario(&c).is_err(), "jitter > 1 accepted");

    let mut c = base.clone();
    c.faults.crash_per_round = 0.1;
    c.faults.recovery.retry_max = 65;
    assert!(simulate_scenario(&c).is_err(), "retry_max > 64 accepted");

    let mut c = base.clone();
    c.faults.crash_per_round = 0.1;
    c.faults.recovery.backoff_base_s = 0.0;
    assert!(simulate_scenario(&c).is_err(), "zero backoff accepted");

    // Faults replay the engine's recorded unit times; the DES oracle
    // records none.
    let mut c = base.clone();
    c.faults.crash_per_round = 0.1;
    c.engine.backend = RoundBackend::Des;
    let err = simulate_scenario(&c).unwrap_err().to_string();
    assert!(err.contains("analytic engine"), "unexpected error: {err}");

    // A round deadline has no barrier to cut under buffered aggregation.
    let mut c = base;
    c.faults.deadline_s = 5.0;
    c.aggregation = AggregationMode::Async;
    let err = simulate_scenario(&c).unwrap_err().to_string();
    assert!(err.contains("sync aggregation"), "unexpected error: {err}");
}

#[test]
fn async_faults_run_deterministically_and_account() {
    let _g = lock();
    for algo in ALGOS {
        let mut c = hazards(&cfg(ScenarioKind::LossyRadio, algo), 0.08, 0.1, 0.05);
        c.aggregation = AggregationMode::Async;
        c.async_agg.buffer_size = 3;
        c.async_agg.staleness_cap = 4;
        c.engine.threads = 1;
        let mut four = c.clone();
        four.engine.threads = 4;
        let a = simulate_scenario(&c).unwrap();
        let b = simulate_scenario(&four).unwrap();
        assert_eq!(a.result.rounds.len(), ROUNDS, "{algo:?}");
        assert_eq!(
            fingerprint(&a.result.rounds),
            fingerprint(&b.result.rounds),
            "{algo:?}: async faulted trace depends on thread count"
        );
        assert_eq!(a.events, b.events, "{algo:?}: merge events diverged");
        let mut activity = 0usize;
        for r in &a.result.rounds {
            // Starts in one merge window are bounded by the fleet plus churn
            // rejoins, so failures and losses can never exceed 2× the fleet.
            assert!(r.faults.n_failed <= 2 * N_CLIENTS, "{algo:?} window {}", r.round);
            assert!(r.faults.n_lost_updates <= 2 * N_CLIENTS, "{algo:?} window {}", r.round);
            assert!(r.faults.recovery_s.is_finite() && r.faults.recovery_s >= 0.0);
            activity += r.faults.n_failed + r.faults.n_retries + r.faults.n_lost_updates;
        }
        assert!(activity > 0, "{algo:?}: async hazards never fired");
    }
}

/// Scratch directory for exporter output (inside `target/`, never committed).
fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("target/test-faults");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn fault_counters_populate_the_registry_and_the_trace() {
    let _g = lock();
    registry::set_enabled(true);
    registry::reset();
    let trace_path = out_dir().join("faults.trace.json");
    let trace_path = trace_path.to_str().unwrap().to_string();
    let mut c = hazards(
        &cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing),
        0.15,
        0.2,
        0.1,
    );
    c.telemetry.enabled = true;
    c.telemetry.sample_every = 1;
    c.telemetry.trace_out = Some(trace_path.clone());
    let run = simulate_scenario(&c).unwrap();
    let snap = registry::snapshot();
    let retries: usize = run.result.rounds.iter().map(|r| r.faults.n_retries).sum();
    let lost: usize = run.result.rounds.iter().map(|r| r.faults.n_lost_updates).sum();
    assert!(snap.counter(Counter::FaultsInjected.name()) > 0);
    assert_eq!(snap.counter(Counter::FaultRetries.name()), retries as u64);
    assert_eq!(snap.counter(Counter::FaultLostUpdates.name()), lost as u64);

    // Every sampled round exports its fault events to the JSONL stream.
    let jsonl = std::fs::read_to_string(format!("{trace_path}.events.jsonl")).unwrap();
    let mut faults = 0usize;
    for line in jsonl.lines().filter(|l| !l.trim().is_empty()) {
        let obj = Json::parse(line).unwrap();
        if obj.get("type").and_then(Json::as_str) != Some("fault") {
            continue;
        }
        faults += 1;
        let kind = obj.get("kind").and_then(Json::as_str).unwrap();
        assert!(
            matches!(kind, "crash" | "link_drop" | "uplink_loss" | "deadline"),
            "unexpected fault kind {kind:?}"
        );
        assert!(obj.get("round").is_some());
        assert!(obj.get("t_s").is_some());
        assert!(obj.get("retries").is_some());
        assert!(obj.get("lost").is_some());
    }
    assert!(faults > 0, "no fault events reached the JSONL stream");
    registry::set_enabled(false);
    registry::reset();
}
