//! Split-planning property suite (DESIGN.md §7).
//!
//! Pins the subsystem's two contracts:
//!
//! 1. **`Paper` fidelity** — the default policy reproduces the paper's
//!    `split_lengths(f_i, f_j, W)` rule *exactly* (same cut for every pair,
//!    bit-identical round times through the engine), so all existing presets
//!    are unchanged.
//! 2. **`Optimal` dominance** — the argmin policy is never slower than
//!    `Paper` under the analytic kernel (≤ 1e-9), across randomized fleets,
//!    profiles, schedules and rates, and equals the exhaustive per-cut
//!    minimum.

use fedpairing::config::{
    ChannelConfig, ExperimentConfig, ModelPreset, SplitConfig, SplitPolicy,
};
use fedpairing::pairing::graph::ClientGraph;
use fedpairing::pairing::greedy::greedy_matching;
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::compute::split_lengths;
use fedpairing::sim::latency::{Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::split::{plan, plan_cut, predicted_at, PairContext, SplitCostModel};
use fedpairing::util::rng::Rng;

fn split_cfg(policy: SplitPolicy) -> SplitConfig {
    SplitConfig {
        policy,
        ..SplitConfig::default()
    }
}

/// Random profiles spanning shallow/deep/uniform/MLP cost structures.
fn random_profile(rng: &mut Rng) -> ModelProfile {
    match rng.below(5) {
        0 => ModelProfile::resnet18_cifar(),
        1 => ModelProfile::resnet34_cifar(),
        2 => ModelProfile::resnet10_cifar(),
        3 => ModelProfile::mlp(3072, 256, 10, 8),
        _ => ModelProfile::uniform(4 + rng.below(12), 1e7 * (1.0 + rng.f64()), 4096.0),
    }
}

struct Case {
    profile: ModelProfile,
    sched: Schedule,
    comp: fedpairing::config::ComputeConfig,
    f_i: f64,
    f_j: f64,
    n_i: usize,
    n_j: usize,
    rate: f64,
}

fn random_case(rng: &mut Rng) -> Case {
    Case {
        profile: random_profile(rng),
        sched: Schedule {
            batch_size: 16 << rng.below(3),
            epochs: 1 + rng.below(3),
        },
        comp: ExperimentConfig::default().compute,
        f_i: rng.range_f64(0.1e9, 2.0e9),
        f_j: rng.range_f64(0.1e9, 2.0e9),
        n_i: 16 + rng.below(512),
        n_j: 16 + rng.below(512),
        // Spans starved radio links to fat short-range ones.
        rate: 10f64.powf(rng.range_f64(5.0, 9.0)),
    }
}

impl Case {
    fn ctx(&self) -> PairContext<'_> {
        PairContext {
            profile: &self.profile,
            sched: &self.sched,
            comp: &self.comp,
            f_i_hz: self.f_i,
            f_j_hz: self.f_j,
            n_i: self.n_i,
            n_j: self.n_j,
            rate_bps: self.rate,
        }
    }
}

#[test]
fn paper_policy_matches_split_lengths_exactly() {
    let mut rng = Rng::new(0x51D);
    for case in 0..300 {
        let c = random_case(&mut rng);
        let ctx = c.ctx();
        let w = c.profile.w();
        let cut = plan_cut(&split_cfg(SplitPolicy::Paper), &ctx);
        let (l_i, l_j) = split_lengths(c.f_i, c.f_j, w);
        assert_eq!(cut, l_i, "case {case}: paper cut diverged ({}, W={w})", c.profile.name);
        assert_eq!(w - cut, l_j);
        // The full decision prices that exact cut.
        let d = plan(&split_cfg(SplitPolicy::Paper), &ctx);
        assert_eq!(d.cut, l_i);
        assert_eq!(d.predicted_round_s, predicted_at(&ctx, l_i));
    }
}

#[test]
fn optimal_never_slower_than_paper_over_randomized_cases() {
    let mut rng = Rng::new(0x0B71);
    let mut strict_wins = 0usize;
    for case in 0..300 {
        let c = random_case(&mut rng);
        let ctx = c.ctx();
        let paper = plan(&split_cfg(SplitPolicy::Paper), &ctx);
        let opt = plan(&split_cfg(SplitPolicy::Optimal), &ctx);
        assert!(
            opt.predicted_round_s <= paper.predicted_round_s + 1e-9,
            "case {case} ({}): optimal {} slower than paper {}",
            c.profile.name,
            opt.predicted_round_s,
            paper.predicted_round_s
        );
        // Exhaustive argmin cross-check over every feasible cut.
        for cut in 1..c.profile.w() {
            assert!(
                opt.predicted_round_s <= predicted_at(&ctx, cut) + 1e-12,
                "case {case}: cut {cut} beats the claimed argmin"
            );
        }
        if opt.predicted_round_s < paper.predicted_round_s * (1.0 - 1e-9) {
            strict_wins += 1;
        }
    }
    // The planner must actually *move* cuts somewhere in 300 random cases —
    // a do-nothing "optimal" that always echoes the paper cut fails here.
    assert!(
        strict_wins > 0,
        "optimal never strictly improved on the paper cut in 300 cases"
    );
}

#[test]
fn balanced_policy_bounded_and_deterministic() {
    let mut rng = Rng::new(0xBA7A);
    for _ in 0..100 {
        let c = random_case(&mut rng);
        let ctx = c.ctx();
        let w = c.profile.w();
        let a = plan(&split_cfg(SplitPolicy::Balanced), &ctx);
        let b = plan(&split_cfg(SplitPolicy::Balanced), &ctx);
        assert_eq!(a, b, "balanced plan not deterministic");
        assert!((1..w).contains(&a.cut));
        // Faster client never gets the *smaller* FLOP share than it would
        // under an inverted pairing of the same two frequencies.
        let inv = PairContext {
            f_i_hz: c.f_j,
            f_j_hz: c.f_i,
            n_i: c.n_j,
            n_j: c.n_i,
            ..ctx
        };
        let a_inv = plan(&split_cfg(SplitPolicy::Balanced), &inv);
        if c.f_i > c.f_j {
            assert!(
                c.profile.train_flops(0, a.cut) >= c.profile.train_flops(0, a_inv.cut) - 1.0,
                "faster front got fewer FLOPs"
            );
        }
    }
}

#[test]
fn engine_rounds_under_optimal_never_slower_with_pinned_pairing() {
    use fedpairing::config::{EngineConfig, RoundBackend};
    use fedpairing::sim::engine::RoundEngine;
    for seed in [1u64, 7, 23] {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 16;
        cfg.samples_per_client = 96;
        cfg.seed = seed;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(seed));
        let channel = Channel::new(ChannelConfig::default());
        let profile = ModelProfile::resnet18_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: 2,
        };
        let pairs: Vec<(usize, usize)> = (0..8).map(|k| (2 * k, 2 * k + 1)).collect();
        let ecfg = EngineConfig {
            backend: RoundBackend::Analytic,
            threads: 1,
            flow_diagnostics: true,
        };
        let mut paper = RoundEngine::new(&ecfg);
        let mut opt = RoundEngine::new(&ecfg).with_split(split_cfg(SplitPolicy::Optimal));
        let a = paper.fedpairing_round(
            &fleet, &pairs, &[], &profile, &sched, &channel, &cfg.compute, true,
        );
        let b = opt.fedpairing_round(
            &fleet, &pairs, &[], &profile, &sched, &channel, &cfg.compute, true,
        );
        assert!(
            b.total_s <= a.total_s + 1e-9,
            "seed {seed}: optimal round {} slower than paper {}",
            b.total_s,
            a.total_s
        );
        assert!(a.mean_cut.is_finite() && b.mean_cut.is_finite());
    }
}

#[test]
fn co_designed_sparse_with_full_k_equals_co_designed_dense() {
    // The scale suite pins dense≡sparse for eq. (5); the co-designed
    // SplitCost weight must keep that equivalence (same shared weight
    // function, same sort, same tie-breaks).
    for n in [6usize, 11, 16] {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(n as u64));
        let channel = Channel::new(ChannelConfig::default());
        let sched = Schedule {
            batch_size: 32,
            epochs: 2,
        };
        let model = SplitCostModel::new(
            ModelProfile::resnet18_cifar(),
            sched,
            cfg.compute,
            split_cfg(SplitPolicy::Optimal),
        );
        let spec = EdgeWeightSpec::SplitCost(&model);
        let dense = greedy_matching(&ClientGraph::build_spec(&fleet, &channel, spec));
        let g = SparseCandidateGraph::build(&fleet, &channel, spec, n - 1, 0);
        let members: Vec<usize> = (0..n).collect();
        let m = match_candidates(&g, &members);
        assert_eq!(m.pairs, dense, "n={n}");
        assert_eq!(m.solos.len(), n % 2);
    }
}

#[test]
fn split_cost_weight_is_negated_prediction() {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = 6;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(3));
    let channel = Channel::new(ChannelConfig::default());
    let sched = Schedule {
        batch_size: 32,
        epochs: 1,
    };
    let model = SplitCostModel::new(
        ModelProfile::resnet10_cifar(),
        sched,
        cfg.compute,
        split_cfg(SplitPolicy::Optimal),
    );
    let spec = EdgeWeightSpec::SplitCost(&model);
    for i in 0..fleet.n() {
        for j in (i + 1)..fleet.n() {
            let w = spec.weight(&fleet, &channel, i, j);
            assert_eq!(w, -model.predicted_pair_s(&fleet, &channel, i, j));
            assert!(w < 0.0, "pair time must be positive");
        }
    }
}

#[test]
fn metro_deep_scenario_plans_on_resnet34() {
    // metro-deep wiring at a test-scale fleet: sparse backend + optimal
    // planner over the deep profile, engine-free pipeline.
    let mut cfg = ExperimentConfig::preset("metro-deep").expect("metro-deep preset");
    cfg.n_clients = 600; // keep the test fast; still sparse under Auto
    cfg.rounds = 3;
    cfg.split.policy = SplitPolicy::Optimal;
    assert_eq!(cfg.model, ModelPreset::Resnet34);
    let run = fedpairing::fleet::simulate_scenario(&cfg).unwrap();
    assert_eq!(run.result.rounds.len(), 3);
    for r in &run.result.rounds {
        assert!(r.sim_round_s > 0.0);
        assert!((1.0..=17.0).contains(&r.mean_cut), "mean_cut {}", r.mean_cut);
    }
    // The CSV exposes the planned cuts.
    let csv = run.result.to_csv();
    assert!(csv.lines().next().unwrap().ends_with("mean_cut"));
}

#[test]
fn optimal_metro_scale_slice_beats_paper_mean_round() {
    // The acceptance direction on a metro-scale *slice* (same pairing for
    // both policies): optimal's mean simulated round never exceeds paper's.
    let mk = |policy: SplitPolicy| {
        let mut cfg = ExperimentConfig::preset("metro-scale").expect("preset");
        cfg.n_clients = 400;
        cfg.rounds = 4;
        cfg.split.policy = policy;
        cfg.split.co_design = false; // identical pairing for a 1:1 comparison
        fedpairing::fleet::simulate_scenario(&cfg).unwrap()
    };
    let paper = mk(SplitPolicy::Paper);
    let optimal = mk(SplitPolicy::Optimal);
    for (a, b) in paper.result.rounds.iter().zip(&optimal.result.rounds) {
        assert!(
            b.sim_round_s <= a.sim_round_s + 1e-9,
            "round {}: {} > {}",
            a.round,
            b.sim_round_s,
            a.sim_round_s
        );
    }
    assert!(optimal.result.mean_round_s() <= paper.result.mean_round_s() + 1e-9);
}
