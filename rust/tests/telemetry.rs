//! Telemetry integration tests — the only test binary that flips the global
//! registry gate (`registry::set_enabled`). Every test serializes on one
//! mutex because the registry and the gate are process-wide; the library's
//! unit tests never enable telemetry, so no other binary races these.
//!
//! The pinned properties:
//!
//! 1. **Determinism invariant** — telemetry on (including trace export)
//!    produces `RoundRecord` traces bit-identical to telemetry off, at any
//!    thread count, on the stable and lossy-radio presets and for all four
//!    algorithms.
//! 2. **Memo hit-rate** — on the stable preset every round after the first
//!    hits the engine's cross-round memo cache, so the counter-derived rate
//!    is exactly `(rounds − 1)/rounds` and round 1 accounts for all misses.
//! 3. **Disabled path** — with the gate off a full churn run leaves every
//!    counter, gauge and histogram at zero.
//! 4. **Exporters** — the Chrome trace parses, spans are well-formed, pair
//!    lanes respect `top_k_pairs`, the Prometheus snapshot exposes the
//!    derived hit-rate, and the JSONL stream has one event per sampled round.

use fedpairing::config::{Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind};
use fedpairing::coordinator::metrics::RoundRecord;
use fedpairing::fleet::simulate_scenario;
use fedpairing::telemetry::registry::{self, Counter};
use fedpairing::telemetry::export;
use fedpairing::util::json::Json;
use std::sync::Mutex;

/// Process-wide serialization for the global registry gate.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg(kind: ScenarioKind, algo: Algorithm) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = 24;
    c.rounds = 15;
    c.samples_per_client = 128;
    c.algorithm = algo;
    c.scenario = ScenarioConfig::preset(kind);
    c
}

/// Every observable bit of a round record (NaN-safe: compares bit patterns).
type Fp = (usize, usize, u64, u64, u64, [u64; 7], i64, i64, u64);

fn fingerprint(rounds: &[RoundRecord]) -> Vec<Fp> {
    rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.n_alive,
                r.sim_round_s.to_bits(),
                r.sim_total_s.to_bits(),
                r.mean_cut.to_bits(),
                r.stages.stage_s.map(f64::to_bits),
                r.stages.crit_a,
                r.stages.crit_b,
                r.stages.crit_slack_s.to_bits(),
            )
        })
        .collect()
}

/// Scratch directory for exporter output (inside `target/`, never committed).
fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("target/test-telemetry");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn telemetry_and_trace_export_never_perturb_round_records() {
    let _g = lock();
    let dir = out_dir();
    for kind in [ScenarioKind::Stable, ScenarioKind::LossyRadio] {
        for threads in [1usize, 4] {
            let mut off = cfg(kind, Algorithm::FedPairing);
            off.engine.threads = threads;
            let mut on = off.clone();
            on.telemetry.enabled = true;
            on.telemetry.sample_every = 2;
            on.telemetry.trace_out = Some(
                dir.join(format!("perturb-{kind:?}-{threads}.json"))
                    .to_string_lossy()
                    .into_owned(),
            );
            let a = simulate_scenario(&off).unwrap();
            let b = simulate_scenario(&on).unwrap();
            assert_eq!(
                fingerprint(&a.result.rounds),
                fingerprint(&b.result.rounds),
                "{kind:?} threads={threads}: telemetry perturbed the trace"
            );
            assert_eq!(a.trace, b.trace, "{kind:?} threads={threads}: churn diverged");
        }
    }
    // The other three algorithms carry stage breakdowns too — same invariant.
    for algo in [Algorithm::VanillaFL, Algorithm::VanillaSL, Algorithm::SplitFed] {
        let off = cfg(ScenarioKind::LossyRadio, algo);
        let mut on = off.clone();
        on.telemetry.enabled = true;
        let a = simulate_scenario(&off).unwrap();
        let b = simulate_scenario(&on).unwrap();
        assert_eq!(
            fingerprint(&a.result.rounds),
            fingerprint(&b.result.rounds),
            "{algo:?}: telemetry perturbed the trace"
        );
    }
    registry::set_enabled(false);
    registry::reset();
}

#[test]
fn memo_hit_rate_is_total_after_round_one_on_stable() {
    let _g = lock();
    registry::reset();
    let mut c = cfg(ScenarioKind::Stable, Algorithm::FedPairing);
    c.telemetry.enabled = true;
    simulate_scenario(&c).unwrap();
    let snap = registry::snapshot();
    let hits = snap.counter(Counter::MemoHits.name());
    let misses = snap.counter(Counter::MemoMisses.name());
    // Stable fleet, 24 clients → 12 pairs priced once in round 1, then every
    // later round is a pure cache hit.
    assert_eq!(misses, 12, "round 1 should miss once per pair");
    assert_eq!(hits, misses * (c.rounds as u64 - 1), "a later round missed");
    let expect = (c.rounds - 1) as f64 / c.rounds as f64;
    assert!((snap.memo_hit_rate() - expect).abs() < 1e-12);
    // The derived series is exposed in the Prometheus snapshot.
    let prom = export::prometheus(&snap);
    assert!(prom.contains("fedpairing_memo_hit_rate"), "{prom}");
    assert!(prom.contains("fedpairing_memo_hits_total"), "{prom}");
    registry::set_enabled(false);
    registry::reset();
}

#[test]
fn disabled_run_leaves_every_metric_at_zero() {
    let _g = lock();
    registry::set_enabled(false);
    registry::reset();
    // Lossy radio exercises every hook site: memo, kernels, repair,
    // candidates (via sparse backends at scale), mobility, pool chunks.
    let mut c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    c.engine.threads = 4;
    simulate_scenario(&c).unwrap();
    let snap = registry::snapshot();
    assert!(snap.counters.iter().all(|&(_, v)| v == 0), "{:?}", snap.counters);
    assert!(snap.gauges.iter().all(|&(_, v)| v == 0), "{:?}", snap.gauges);
    assert!(snap
        .histos
        .iter()
        .all(|(_, b)| b.iter().all(|&v| v == 0)));
}

#[test]
fn exporters_write_parseable_well_formed_output() {
    let _g = lock();
    registry::reset();
    let dir = out_dir();
    let trace_path = dir.join("golden.json").to_string_lossy().into_owned();
    let mut c = cfg(ScenarioKind::Stable, Algorithm::FedPairing);
    c.n_clients = 16;
    c.rounds = 6;
    c.telemetry.enabled = true;
    c.telemetry.sample_every = 2; // samples rounds 1, 3, 5
    c.telemetry.top_k_pairs = 4;
    c.telemetry.trace_out = Some(trace_path.clone());
    simulate_scenario(&c).unwrap();

    // Chrome trace: parses, and every span is well-formed.
    let trace = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut phases = 0usize;
    let mut lanes = 0usize;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let pid = e.get("pid").unwrap().as_usize().unwrap();
        match ph {
            "X" => {
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let dur = e.get("dur").unwrap().as_f64().unwrap();
                assert!(ts >= 0.0 && dur >= 0.0, "negative span: {e:?}");
                let name = e.get("name").unwrap().as_str().unwrap();
                if pid == 0 {
                    assert!(
                        ["dynamics", "pairing", "engine"].contains(&name),
                        "unknown phase span {name}"
                    );
                    phases += 1;
                } else {
                    assert_eq!(pid, 1);
                    assert!(name.starts_with("pair "), "lane span {name}");
                    // Lane tids are the per-round slowness ranks 0..top_k.
                    assert!(e.get("tid").unwrap().as_usize().unwrap() < 4);
                    lanes += 1;
                }
            }
            "M" => {} // process-name metadata
            other => panic!("unexpected event phase {other}"),
        }
    }
    // 3 sampled rounds × 3 marks (dynamics/pairing/engine).
    assert_eq!(phases, 9, "phase span count");
    // 16 clients → 8 pairs, truncated to top_k = 4, × 3 sampled rounds.
    assert_eq!(lanes, 12, "pair lane count");

    // Prometheus snapshot rides along as `<trace>.prom`.
    let prom = std::fs::read_to_string(format!("{trace_path}.prom")).unwrap();
    assert!(prom.contains("# TYPE fedpairing_memo_hits_total counter"));
    assert!(prom.contains("fedpairing_memo_hit_rate"));

    // JSONL: one round event per sampled round, each carrying the breakdown.
    let jsonl = std::fs::read_to_string(format!("{trace_path}.events.jsonl")).unwrap();
    let rounds: Vec<Json> = jsonl
        .lines()
        .filter(|l| !l.is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(rounds.len(), 3, "sampled-round event count");
    for (ev, round) in rounds.iter().zip([1usize, 3, 5]) {
        assert_eq!(ev.get("type").unwrap().as_str().unwrap(), "round");
        assert_eq!(ev.get("round").unwrap().as_usize().unwrap(), round);
        assert_eq!(ev.get("n_alive").unwrap().as_usize().unwrap(), 16);
        assert!(ev.get("sim_round_s").unwrap().as_f64().unwrap() > 0.0);
        let stages = ev.get("stages").unwrap();
        assert!(stages.get("front_fp").is_some(), "breakdown missing: {ev:?}");
        assert!(stages.get("crit_a").is_some());
    }
    registry::set_enabled(false);
    registry::reset();
}

#[test]
fn hot_path_counters_populate_on_an_enabled_churn_run() {
    let _g = lock();
    registry::reset();
    let mut c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    c.engine.threads = 2;
    c.telemetry.enabled = true;
    simulate_scenario(&c).unwrap();
    let snap = registry::snapshot();
    // Fading re-keys pairs every round → misses and analytic kernel runs.
    assert!(snap.counter(Counter::MemoMisses.name()) > 0);
    assert!(snap.counter(Counter::KernelEvalsAnalytic.name()) > 0);
    // Lossy radio has mobility, so alive clients relocate in the grid.
    assert!(snap.counter(Counter::GridRelocations.name()) > 0);
    // The fleet-alive gauge reflects the last round's participant count.
    let alive = snap
        .gauges
        .iter()
        .find(|(n, _)| *n == "fleet_alive")
        .map(|&(_, v)| v)
        .unwrap();
    assert!(alive >= 1 && alive <= 24, "fleet_alive = {alive}");
    registry::set_enabled(false);
    registry::reset();
}
