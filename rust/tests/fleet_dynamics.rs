//! System-level tests for the fleet-dynamics subsystem: matching-repair
//! invariants under arbitrary departure/arrival sequences, bit-identical
//! churn traces, odd-fleet (near-perfect matching) regressions, and the
//! engine-free scenario driver.

use fedpairing::config::{
    Algorithm, ExperimentConfig, PairingStrategy, ScenarioConfig, ScenarioKind,
};
use fedpairing::fleet::{simulate_scenario, FleetDynamics};
use fedpairing::pairing::graph::{is_perfect_matching, uncovered};
use fedpairing::pairing::{pair_clients, pair_members, repair_matching, Matching};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::proptest::{check, gen_pair, gen_u64, gen_usize, Gen};
use fedpairing::util::rng::Rng;

fn fleet_of(seed: u64, n: usize) -> (Fleet, Channel, ExperimentConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    cfg.seed = seed;
    cfg.samples_per_client = 128;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(seed));
    (fleet, Channel::new(cfg.channel), cfg)
}

fn weight_fn(fleet: &Fleet, channel: &Channel) -> impl Fn(usize, usize) -> f64 {
    let freqs = fleet.freqs_hz.clone();
    let pos = fleet.positions.clone();
    let ch = channel.clone();
    move |a, b| {
        let df = (freqs[a] - freqs[b]) / 1e9;
        df * df + 2e-9 * ch.rate(&pos[a], &pos[b])
    }
}

// ---------------------------------------------------------------------------
// Property (a): a repaired matching is still a valid matching after ANY
// departure/arrival sequence.
// ---------------------------------------------------------------------------

#[test]
fn prop_repair_valid_after_any_departure_sequence() {
    check(
        40,
        gen_pair(gen_u64(0, u64::MAX / 2), gen_usize(4, 16)),
        |&(seed, n)| {
            let (fleet, ch, cfg) = fleet_of(seed, n);
            let mut rng = Rng::new(seed ^ 0xDEAD);
            let all: Vec<usize> = (0..n).collect();
            let mut m = pair_members(
                PairingStrategy::Greedy,
                &fleet,
                &ch,
                cfg.alpha,
                cfg.beta,
                &mut rng,
                &all,
            );
            if !m.is_valid_over(&all) {
                return false;
            }
            // Random alive-set walk: each step flips a few clients' liveness
            // (departures AND re-arrivals), always keeping >= 1 alive.
            let mut alive: Vec<bool> = vec![true; n];
            for _ in 0..12 {
                let flips = 1 + rng.below(3);
                for _ in 0..flips {
                    let c = rng.below(n);
                    let alive_count = alive.iter().filter(|&&a| a).count();
                    if alive[c] && alive_count <= 1 {
                        continue; // never empty the fleet
                    }
                    alive[c] = !alive[c];
                }
                let members: Vec<usize> = (0..n).filter(|&c| alive[c]).collect();
                repair_matching(&mut m, &members, weight_fn(&fleet, &ch));
                if !m.is_valid_over(&members) {
                    return false;
                }
                // Near-perfect: solo count == parity of the alive set.
                if m.solos.len() != members.len() % 2 {
                    return false;
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------------------
// Property (b): identical seeds + scenario produce bit-identical churn traces.
// ---------------------------------------------------------------------------

#[test]
fn prop_identical_seed_scenario_gives_identical_trace() {
    check(
        24,
        Gen::new(|rng| {
            let kind = ScenarioKind::ALL[rng.below(ScenarioKind::ALL.len())];
            (rng.next_u64() >> 1, 4 + rng.below(16), kind)
        }),
        |&(seed, n, kind)| {
            let mut cfg = ExperimentConfig::default();
            cfg.seed = seed;
            cfg.n_clients = n;
            cfg.rounds = 25;
            cfg.scenario = ScenarioConfig::preset(kind);
            FleetDynamics::trace(&cfg) == FleetDynamics::trace(&cfg)
        },
    );
}

#[test]
fn prop_simulated_round_times_deterministic() {
    check(10, gen_u64(0, u64::MAX / 2), |&seed| {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = seed;
        cfg.n_clients = 10;
        cfg.rounds = 12;
        cfg.samples_per_client = 200;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        let a = simulate_scenario(&cfg).unwrap();
        let b = simulate_scenario(&cfg).unwrap();
        a.trace == b.trace
            && a.result
                .rounds
                .iter()
                .zip(&b.result.rounds)
                .all(|(x, y)| x.sim_round_s == y.sim_round_s && x.n_alive == y.n_alive)
    });
}

// ---------------------------------------------------------------------------
// Odd-fleet regressions (n_clients = 7)
// ---------------------------------------------------------------------------

#[test]
fn odd_fleet_n7_every_strategy_leaves_one_solo() {
    let (fleet, ch, cfg) = fleet_of(41, 7);
    for strat in [
        PairingStrategy::Greedy,
        PairingStrategy::Random,
        PairingStrategy::Location,
        PairingStrategy::Compute,
        PairingStrategy::Exact,
    ] {
        let mut rng = Rng::new(42);
        let pairs = pair_clients(strat, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng);
        assert_eq!(pairs.len(), 3, "{strat:?}");
        assert!(is_perfect_matching(7, &pairs), "{strat:?}: {pairs:?}");
        assert_eq!(uncovered(7, &pairs).len(), 1, "{strat:?}");
    }
}

#[test]
fn odd_fleet_config_validates_and_simulates() {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = 7;
    cfg.rounds = 5;
    cfg.samples_per_client = 100;
    cfg.validate().unwrap(); // formerly rejected odd FedPairing fleets
    let run = simulate_scenario(&cfg).unwrap();
    assert_eq!(run.result.rounds.len(), 5);
    assert!(run.result.rounds.iter().all(|r| r.n_alive == 7));
    assert!(run.result.rounds.iter().all(|r| r.sim_round_s > 0.0));
}

// ---------------------------------------------------------------------------
// Acceptance-criteria path: flash-crowd FedPairing run end to end
// ---------------------------------------------------------------------------

#[test]
fn flash_crowd_fedpairing_departs_repairs_and_records() {
    let mut cfg = ExperimentConfig::default();
    cfg.algorithm = Algorithm::FedPairing;
    cfg.rounds = 30;
    cfg.samples_per_client = 250;
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::FlashCrowd);
    let run = simulate_scenario(&cfg).unwrap();
    // At least one client departed mid-training...
    assert!(run.total_departures() > 0);
    // ...the matching was incrementally repaired...
    assert!(run.repaired_rounds > 0);
    // ...and the RunResult records per-round alive-client counts.
    assert_eq!(run.result.rounds.len(), 30);
    assert!(run.result.mean_alive() > 0.0);
    let csv = run.result.to_csv();
    assert!(csv.starts_with("round,n_alive,"));
    // The flash cohort shows up as a jump in participation.
    let max_alive = run.result.rounds.iter().map(|r| r.n_alive).max().unwrap();
    assert!(max_alive > cfg.n_clients, "cohort never exceeded base fleet");
}

#[test]
fn restricted_matching_composes_with_repair() {
    // A transient failure must not mutate the stored matching, while a
    // durable departure must.
    let (fleet, ch, cfg) = fleet_of(55, 8);
    let mut rng = Rng::new(56);
    let all: Vec<usize> = (0..8).collect();
    let mut m = pair_members(
        PairingStrategy::Greedy,
        &fleet,
        &ch,
        cfg.alpha,
        cfg.beta,
        &mut rng,
        &all,
    );
    let stored = m.clone();
    // Transient: restrict only.
    let present: Vec<usize> = (1..8).collect();
    let eff = m.restricted_to(&present);
    assert_eq!(m, stored, "restriction must not mutate");
    assert_eq!(eff.solos.len(), 1);
    // Durable: repair mutates.
    repair_matching(&mut m, &present, weight_fn(&fleet, &ch));
    assert_ne!(m, stored);
    assert!(m.is_valid_over(&present));
}

#[test]
fn matching_members_and_validity_helpers() {
    let m = Matching {
        pairs: vec![(4, 1), (2, 7)],
        solos: vec![5],
    };
    assert_eq!(m.members(), vec![1, 2, 4, 5, 7]);
    assert!(m.is_valid_over(&[1, 2, 4, 5, 7]));
    assert!(!m.is_valid_over(&[1, 2, 4, 5])); // extra member in matching
    assert!(!m.is_valid_over(&[1, 2, 3, 4, 5, 7])); // 3 uncovered
}
