//! Cross-module integration tests: the full pipeline (config → fleet → data
//! → pairing → runtime → coordinator → metrics), the CLI binary, and the
//! latency-model ↔ protocol consistency contract.
//!
//! Runtime-dependent tests skip cleanly when `make artifacts` hasn't run.

use fedpairing::config::{Algorithm, DataDistribution, ExperimentConfig, PairingStrategy};
use fedpairing::coordinator::{run_experiment, Experiment};
use fedpairing::coordinator::protocol;
use fedpairing::data::synth::{SynthCifar, NUM_CLASSES};
use fedpairing::model::ModelMeta;
use fedpairing::sim::latency::CLASSES;
use std::process::Command;

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not built");
    }
    ok
}

fn quick(algo: Algorithm) -> ExperimentConfig {
    let mut c = ExperimentConfig::preset("quick").unwrap();
    c.algorithm = algo;
    c.rounds = 3;
    c.samples_per_client = 64;
    c.test_samples = 128;
    c
}

// ---------------------------------------------------------------------------
// full pipeline
// ---------------------------------------------------------------------------

#[test]
fn fedpairing_learns_above_chance_quickly() {
    if !artifacts_ready() {
        return;
    }
    let res = run_experiment(quick(Algorithm::FedPairing)).unwrap();
    // 10-class chance = 0.1; three rounds on the quick task must clear 2x.
    assert!(
        res.final_acc() > 0.2,
        "final acc {} not above chance",
        res.final_acc()
    );
    // training loss decreased from round 1 to last
    let first = res.rounds.first().unwrap().train_loss;
    let last = res.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn pairing_strategy_affects_time_not_learning_health() {
    if !artifacts_ready() {
        return;
    }
    for strat in [PairingStrategy::Greedy, PairingStrategy::Random] {
        let mut cfg = quick(Algorithm::FedPairing);
        cfg.pairing = strat;
        let res = run_experiment(cfg).unwrap();
        assert!(res.final_acc() > 0.15, "{strat:?}: {}", res.final_acc());
    }
}

#[test]
fn sim_round_times_consistent_with_latency_module() {
    if !artifacts_ready() {
        return;
    }
    // Per-round simulated time must be constant across rounds (static fleet)
    // and ordered FL > FedPairing for the same fleet.
    let fp = run_experiment(quick(Algorithm::FedPairing)).unwrap();
    let fl = run_experiment(quick(Algorithm::VanillaFL)).unwrap();
    for w in fp.rounds.windows(2) {
        assert_eq!(w[0].sim_round_s, w[1].sim_round_s);
    }
    assert!(fl.rounds[0].sim_round_s > fp.rounds[0].sim_round_s);
}

#[test]
fn metrics_files_written_and_parse_back() {
    if !artifacts_ready() {
        return;
    }
    let mut cfg = quick(Algorithm::FedPairing);
    cfg.name = "itest".into();
    let res = run_experiment(cfg).unwrap();
    let dir = std::env::temp_dir().join("fp_itest_out");
    let dir = dir.to_str().unwrap();
    let (csv, json) = res.save(dir).unwrap();
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), 1 + res.rounds.len());
    let parsed = fedpairing::util::json::Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(
        parsed.get("config").unwrap().get("name").unwrap().as_str(),
        Some("itest")
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn experiment_reusable_for_multiple_evaluations() {
    if !artifacts_ready() {
        return;
    }
    let mut exp = Experiment::new(quick(Algorithm::FedPairing)).unwrap();
    let params = exp.engine.init_params(3).unwrap();
    let (l1, a1) = exp.evaluate(&params).unwrap();
    let (l2, a2) = exp.evaluate(&params).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    assert!((0.0..=1.0).contains(&a1));
}

// ---------------------------------------------------------------------------
// config / manifest interop
// ---------------------------------------------------------------------------

#[test]
fn config_file_roundtrip_through_disk() {
    let mut cfg = ExperimentConfig::preset("fig3").unwrap();
    cfg.algorithm = Algorithm::SplitFed;
    cfg.seed = 99;
    let path = std::env::temp_dir().join("fp_cfg_itest.json");
    std::fs::write(&path, cfg.to_json().to_string_pretty(2)).unwrap();
    let loaded = ExperimentConfig::load(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded.algorithm, Algorithm::SplitFed);
    assert_eq!(loaded.seed, 99);
    assert_eq!(
        loaded.distribution,
        DataDistribution::ClassShards { classes_per_client: 2 }
    );
    let _ = std::fs::remove_file(path);
}

#[test]
fn manifest_profile_agrees_with_latency_classes() {
    if !artifacts_ready() {
        return;
    }
    let meta = ModelMeta::load("artifacts").unwrap();
    assert_eq!(meta.classes, CLASSES, "latency CLASSES constant out of sync");
    assert_eq!(meta.classes, NUM_CLASSES, "synth NUM_CLASSES out of sync");
    // manifest ↔ profile param agreement
    let p = meta.profile();
    assert_eq!(p.params(0, p.w()), meta.n_params);
}

#[test]
fn protocol_bytes_match_latency_model_inputs() {
    // The latency simulator charges act+g_logits up / logits+g_act down per
    // batch; protocol byte helpers must produce identical totals.
    let (b, h, c) = (32, 256, 10);
    let up = protocol::owner_to_helper_bytes(b, h, c);
    let down = protocol::helper_to_owner_bytes(b, h, c);
    assert_eq!(up, (b * h * 4 + b * c * 4) as f64);
    assert_eq!(down, (b * c * 4 + b * h * 4) as f64);
}

#[test]
fn synth_testset_disjoint_from_training_indices() {
    use fedpairing::data::partition::partition;
    use fedpairing::util::rng::Rng;
    let mut rng = Rng::new(1);
    let shards = partition(&mut rng, 20, 2500, &DataDistribution::Iid);
    let max_train_idx = shards
        .iter()
        .flat_map(|s| s.coords.iter().map(|&(_, i)| i))
        .max()
        .unwrap();
    assert!(max_train_idx < fedpairing::data::synth::TEST_INDEX_BASE);
    // and test samples exist beyond that base
    let gen = SynthCifar::new(1, 1.0);
    let t = gen.test_set(10);
    assert_eq!(t.len(), 10);
}

// ---------------------------------------------------------------------------
// CLI binary
// ---------------------------------------------------------------------------

#[test]
fn cli_help_and_pair_and_latency() {
    let bin = env!("CARGO_BIN_EXE_fedpairing");
    let out = Command::new(bin).arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("run"), "{text}");
    assert!(text.contains("latency"));

    let out = Command::new(bin)
        .args(["pair", "--clients", "8", "--strategy", "greedy"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert_eq!(text.matches('(').count() >= 4, true, "{text}");

    let out = Command::new(bin)
        .args(["latency", "--clients", "10", "--samples", "100"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table I"));
    assert!(text.contains("fedpairing"));
}

#[test]
fn cli_rejects_unknown_flags_and_bad_values() {
    let bin = env!("CARGO_BIN_EXE_fedpairing");
    let out = Command::new(bin).args(["run", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    let out = Command::new(bin)
        .args(["pair", "--strategy", "quantum"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn cli_info_reads_manifest() {
    if !artifacts_ready() {
        return;
    }
    let bin = env!("CARGO_BIN_EXE_fedpairing");
    let out = Command::new(bin).arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resnet-mlp"));
    assert!(text.contains("front_fwd_1"));
}
