//! Fleet-scale suite for the sparse candidate-graph pairing backend.
//!
//! Everything here is named `scale_*` so CI's release-mode smoke job can run
//! exactly this surface (`cargo test --release -q scale_`):
//!
//! * sparse/dense equivalence — with `k_near ≥ n−1` the sparse backend's
//!   candidate set degenerates to the complete graph and must reproduce the
//!   dense greedy matching **exactly**;
//! * matching validity and deterministic churn traces at n = 5 000;
//! * the acceptance path: a metro-scale fleet (100k clients in release,
//!   20k in debug so `cargo test -q` stays usable) completes its initial
//!   pairing plus one incremental repair without materializing O(n²) edges;
//! * `PairingStrategy::Exact` past the DP limit falls back to greedy instead
//!   of aborting the run.

use fedpairing::config::{BackendMode, ExperimentConfig, PairingBackendConfig, PairingStrategy};
use fedpairing::fleet::{maintain_matching, FleetDynamics};
use fedpairing::pairing::graph::{is_perfect_matching, ClientGraph};
use fedpairing::pairing::greedy::greedy_matching;
use fedpairing::pairing::{
    match_candidates, pair_clients, pair_clients_backend, EdgeWeightSpec, Matching,
    SparseCandidateGraph,
};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::proptest::{check, Gen};
use fedpairing::util::rng::Rng;

fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    (
        Fleet::sample(&cfg, &mut Rng::new(seed)),
        Channel::new(cfg.channel),
    )
}

fn sparse_backend() -> PairingBackendConfig {
    PairingBackendConfig {
        mode: BackendMode::Sparse,
        ..PairingBackendConfig::default()
    }
}

#[test]
fn scale_sparse_dense_equivalence_property() {
    // Sparse with k ≥ n−1 reproduces the dense greedy matching exactly —
    // pair for pair, in pick order — on arbitrary small fleets.
    check(
        30,
        Gen::new(|rng| (2 + rng.below(15), rng.next_u64() % 1000)),
        |&(n, seed)| {
            let (f, ch) = fleet(n, seed);
            let dense = greedy_matching(&ClientGraph::build(&f, &ch, 1.0, 5e-10));
            let spec = EdgeWeightSpec::Eq5 {
                alpha: 1.0,
                beta: 5e-10,
            };
            let g = SparseCandidateGraph::build(&f, &ch, spec, n - 1, 0);
            let members: Vec<usize> = (0..n).collect();
            let m = match_candidates(&g, &members);
            m.pairs == dense && m.solos.len() == n % 2
        },
    );
}

#[test]
fn scale_sparse_equivalence_survives_freq_band() {
    // Adding frequency-band candidates on top of a complete geometric set
    // must not change the matching (duplicates dedup away).
    for n in [4usize, 9, 14] {
        let (f, ch) = fleet(n, 7 * n as u64);
        let dense = greedy_matching(&ClientGraph::build(&f, &ch, 1.0, 5e-10));
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, n - 1, 4);
        let members: Vec<usize> = (0..n).collect();
        assert_eq!(match_candidates(&g, &members).pairs, dense, "n={n}");
    }
}

#[test]
fn scale_sparse_validity_all_strategies_n5000() {
    let n = 5_000;
    let (f, ch) = fleet(n, 42);
    let backend = sparse_backend();
    for strat in [
        PairingStrategy::Greedy,
        PairingStrategy::Random,
        PairingStrategy::Location,
        PairingStrategy::Compute,
    ] {
        let mut rng = Rng::new(1);
        let pairs = pair_clients_backend(&backend, strat, &f, &ch, 1.0, 5e-10, &mut rng);
        assert!(is_perfect_matching(n, &pairs), "{strat:?} invalid at n={n}");
    }
}

#[test]
fn scale_sparse_pairing_deterministic_n5000() {
    let n = 5_000;
    let (f, ch) = fleet(n, 9);
    let backend = sparse_backend();
    let a = pair_clients_backend(
        &backend,
        PairingStrategy::Greedy,
        &f,
        &ch,
        1.0,
        5e-10,
        &mut Rng::new(3),
    );
    let b = pair_clients_backend(
        &backend,
        PairingStrategy::Greedy,
        &f,
        &ch,
        1.0,
        5e-10,
        &mut Rng::new(3),
    );
    assert_eq!(a, b);
}

/// One churn run: per-round events + matching snapshots.
fn churn_run(cfg: &ExperimentConfig, rounds: usize) -> Vec<(usize, Matching)> {
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut matching = None;
    let mut out = Vec::with_capacity(rounds);
    for round in 1..=rounds {
        let ev = dynamics.step(round);
        let channel = dynamics.channel();
        maintain_matching(&mut matching, &dynamics, &ev, &channel, cfg, None, &mut pairing_rng);
        let m = matching.clone().expect("matching initialized");
        assert!(
            m.is_valid_over(&dynamics.alive_indices()),
            "round {round}: invalid matching"
        );
        out.push((ev.n_alive, m));
    }
    out
}

#[test]
fn scale_churn_trace_deterministic_n5000() {
    let mut cfg = ExperimentConfig::preset("metro-scale").unwrap();
    cfg.n_clients = 5_000;
    cfg.seed = 23;
    let a = churn_run(&cfg, 6);
    let b = churn_run(&cfg, 6);
    assert_eq!(a, b, "metro churn + sparse re-pairing not deterministic");
    // Churn actually happened (otherwise the repair path went untested).
    assert!(
        a.iter().map(|(alive, _)| alive).min() != a.iter().map(|(alive, _)| alive).max(),
        "alive count never moved"
    );
}

#[test]
fn scale_metro_pairing_and_incremental_repair() {
    // The acceptance path. Release runs the full 100k fleet; debug keeps
    // `cargo test -q` usable at 20k.
    let n: usize = if cfg!(debug_assertions) { 20_000 } else { 100_000 };
    let mut cfg = ExperimentConfig::preset("metro-scale").unwrap();
    cfg.n_clients = n;
    cfg.seed = 17;
    let t0 = std::time::Instant::now();
    // No O(n²) edge materialization: the candidate set is O(n·k).
    let (f, ch) = fleet(n, cfg.seed);
    let spec = EdgeWeightSpec::Eq5 {
        alpha: cfg.alpha,
        beta: cfg.beta,
    };
    let g = SparseCandidateGraph::build(&f, &ch, spec, cfg.backend.k_near, cfg.backend.k_freq);
    assert!(
        g.edges().len() <= n * (cfg.backend.k_near + cfg.backend.k_freq),
        "candidate set not O(n·k): {} edges",
        g.edges().len()
    );
    // Full pairing + one churn step + incremental repair through the real
    // fleet path (dynamics grid, sparse pool matcher).
    let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(&cfg, base);
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut matching = None;
    let ev = dynamics.step(1);
    let channel = dynamics.channel();
    assert!(maintain_matching(
        &mut matching,
        &dynamics,
        &ev,
        &channel,
        &cfg,
        None,
        &mut pairing_rng
    ));
    let m0 = matching.clone().unwrap();
    let alive = dynamics.alive_indices();
    assert!(m0.is_valid_over(&alive));
    // Near-perfect over the alive set: ⌊alive/2⌋ pairs, parity solo.
    assert_eq!(m0.pairs.len(), alive.len() / 2);
    assert_eq!(m0.solos.len(), alive.len() % 2);
    // Round 2: metro churn moves ~1% of the fleet — the repair pool is far
    // past the dense threshold, so this exercises the grid-local path.
    let ev = dynamics.step(2);
    assert!(!ev.departed.is_empty(), "metro scenario produced no churn");
    let channel = dynamics.channel();
    let changed =
        maintain_matching(&mut matching, &dynamics, &ev, &channel, &cfg, None, &mut pairing_rng);
    assert!(changed, "repair did not run");
    let m1 = matching.unwrap();
    assert!(m1.is_valid_over(&dynamics.alive_indices()));
    // Incremental: the overwhelming majority of healthy pairs survive.
    let before: std::collections::HashSet<(usize, usize)> = m0.pairs.iter().copied().collect();
    let kept = m1.pairs.iter().filter(|p| before.contains(p)).count();
    assert!(
        kept * 10 >= m1.pairs.len() * 8,
        "repair re-shuffled too much: kept {kept} of {}",
        m1.pairs.len()
    );
    if !cfg!(debug_assertions) {
        assert!(
            t0.elapsed().as_secs_f64() < 60.0,
            "metro pairing + repair too slow: {:?}",
            t0.elapsed()
        );
    }
}

#[test]
fn scale_exact_falls_back_to_greedy_past_dp_limit() {
    // Used to abort with `assert!(n_eff <= MAX_N)`; now a documented greedy
    // fallback keeps the run alive.
    let n = 40;
    let (f, ch) = fleet(n, 5);
    let mut rng = Rng::new(2);
    let pairs = pair_clients(PairingStrategy::Exact, &f, &ch, 1.0, 5e-10, &mut rng);
    assert!(is_perfect_matching(n, &pairs));
    let greedy = greedy_matching(&ClientGraph::build(&f, &ch, 1.0, 5e-10));
    assert_eq!(pairs, greedy, "fallback should be the greedy matching");
}
