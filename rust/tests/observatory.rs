//! Distribution-observatory integration tests (DESIGN.md §12).
//!
//! Pinned properties:
//!
//! 1. **Gate invariance** — the quantile lanes and fairness column on
//!    `RoundRecord` are fed unconditionally, so enabling telemetry (the
//!    registry gate + trace export) leaves every record bit-identical.
//! 2. **Thread determinism** — the observatory (sketches + ledger) and the
//!    per-round lanes are bit-identical at `--threads` 1 and 4, on the
//!    stable and lossy-radio presets, for all four algorithms.
//! 3. **Async feed** — buffered-aggregation windows populate the lanes, the
//!    staleness/wait sketches and the ledger the same way sync rounds do.
//! 4. **Report round trip** — `fedpairing report` replaying a streamed
//!    `.stream.csv` / `.stream.jsonl` reproduces the in-run lanes and
//!    fairness bit-exactly, both loaders agree, and the rendered analyses
//!    are complete.

use fedpairing::config::{
    AggregationMode, Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind,
};
use fedpairing::coordinator::metrics::RoundRecord;
use fedpairing::fleet::simulate_scenario;
use fedpairing::telemetry::registry;
use fedpairing::telemetry::report::Report;
use std::sync::Mutex;

/// Serializes the tests that flip the process-wide registry gate.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg(kind: ScenarioKind, algo: Algorithm) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = 24;
    c.rounds = 12;
    c.samples_per_client = 128;
    c.algorithm = algo;
    c.scenario = ScenarioConfig::preset(kind);
    c
}

/// The observability columns of a record, as bit patterns (NaN-safe).
type Lanes = (usize, u64, u64, u64, u64);

fn lane_bits(rounds: &[RoundRecord]) -> Vec<Lanes> {
    rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.mk_p50_s.to_bits(),
                r.mk_p90_s.to_bits(),
                r.mk_p99_s.to_bits(),
                r.fairness.to_bits(),
            )
        })
        .collect()
}

/// Scratch directory for stream output (inside `target/`, never committed).
fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("target/test-observatory");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn lanes_and_fairness_ignore_the_telemetry_gate() {
    let _g = lock();
    for kind in [ScenarioKind::Stable, ScenarioKind::LossyRadio] {
        let off = cfg(kind, Algorithm::FedPairing);
        let mut on = off.clone();
        on.telemetry.enabled = true;
        let a = simulate_scenario(&off).unwrap();
        let b = simulate_scenario(&on).unwrap();
        assert_eq!(
            lane_bits(&a.result.rounds),
            lane_bits(&b.result.rounds),
            "{kind:?}: the telemetry gate perturbed the observability columns"
        );
        assert_eq!(
            a.result.observatory, b.result.observatory,
            "{kind:?}: the telemetry gate perturbed the observatory"
        );
    }
    registry::set_enabled(false);
    registry::reset();
}

#[test]
fn observatory_is_bit_identical_across_thread_counts() {
    let _g = lock();
    for kind in [ScenarioKind::Stable, ScenarioKind::LossyRadio] {
        for algo in [
            Algorithm::FedPairing,
            Algorithm::VanillaFL,
            Algorithm::VanillaSL,
            Algorithm::SplitFed,
        ] {
            let mut one = cfg(kind, algo);
            one.engine.threads = 1;
            let mut four = one.clone();
            four.engine.threads = 4;
            let a = simulate_scenario(&one).unwrap();
            let b = simulate_scenario(&four).unwrap();
            assert_eq!(
                lane_bits(&a.result.rounds),
                lane_bits(&b.result.rounds),
                "{kind:?}/{algo:?}: lanes diverged across thread counts"
            );
            assert_eq!(
                a.result.observatory, b.result.observatory,
                "{kind:?}/{algo:?}: observatory diverged across thread counts"
            );
            // The run actually produced distribution data: every round has
            // monotone finite lanes and the sketch saw every unit.
            for r in &a.result.rounds {
                if r.n_alive == 0 {
                    continue; // no units this round -> NaN lanes by contract
                }
                assert!(r.mk_p50_s.is_finite(), "{kind:?}/{algo:?} round {}", r.round);
                assert!(r.mk_p50_s <= r.mk_p90_s && r.mk_p90_s <= r.mk_p99_s);
            }
            assert!(a.result.observatory.unit_makespan.count() > 0);
            let last = a.result.rounds.last().unwrap();
            assert!(last.fairness > 0.0 && last.fairness <= 1.0 + 1e-12);
        }
    }
}

#[test]
fn async_windows_feed_lanes_sketches_and_ledger() {
    let _g = lock();
    let mut c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = 4;
    c.async_agg.staleness_cap = 8;
    let run = simulate_scenario(&c).unwrap();
    let obs = &run.result.observatory;
    assert!(obs.unit_makespan.count() > 0, "no units fed");
    assert!(obs.staleness.count() > 0, "no staleness samples fed");
    assert!(!obs.ledger.is_empty(), "ledger never credited");
    // Some window recorded units, so some record carries finite lanes.
    assert!(run.result.rounds.iter().any(|r| r.mk_p99_s.is_finite()));
    // No barrier in async mode: nobody accrues wait time.
    let any_wait = (0..obs.ledger.len()).any(|id| obs.ledger.wait_of(id) != 0.0);
    assert!(!any_wait, "async windows must not charge barrier wait");
    // Fairness is cumulative and lands in (0, 1].
    let last = run.result.rounds.last().unwrap();
    assert!(last.fairness > 0.0 && last.fairness <= 1.0 + 1e-12);
}

#[test]
fn report_reproduces_streamed_lanes_bit_exactly() {
    let _g = lock();
    let dir = out_dir();
    let mut c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    c.name = "obsgold".into();
    c.faults.crash_per_round = 0.05;
    c.stream_out = Some(dir.to_string_lossy().into_owned());
    let run = simulate_scenario(&c).unwrap();
    let base = dir.join(format!(
        "{}_{}_{}",
        c.name,
        c.algorithm.name(),
        c.distribution.name()
    ));
    let base = base.to_string_lossy();

    let csv = Report::load(&format!("{base}.stream.csv")).unwrap();
    assert_eq!(csv.rows.len(), run.result.rounds.len());
    for (row, rec) in csv.rows.iter().zip(&run.result.rounds) {
        assert_eq!(row.round, rec.round);
        assert_eq!(row.n_alive, rec.n_alive);
        assert_eq!(row.lanes.p50_s.to_bits(), rec.mk_p50_s.to_bits());
        assert_eq!(row.lanes.p90_s.to_bits(), rec.mk_p90_s.to_bits());
        assert_eq!(row.lanes.p99_s.to_bits(), rec.mk_p99_s.to_bits());
        assert_eq!(row.fairness.to_bits(), rec.fairness.to_bits());
        assert_eq!(row.recovery_s.to_bits(), rec.faults.recovery_s.to_bits());
        for (s, t) in row.stage_s.iter().zip(rec.stages.stage_s) {
            assert_eq!(s.to_bits(), t.to_bits());
        }
    }

    // Both stream formats load to the same analysis inputs.
    let jsonl = Report::load(&format!("{base}.stream.jsonl")).unwrap();
    assert_eq!(jsonl.rows.len(), csv.rows.len());
    for (a, b) in jsonl.rows.iter().zip(&csv.rows) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.lanes.p99_s.to_bits(), b.lanes.p99_s.to_bits());
        assert_eq!(a.fairness.to_bits(), b.fairness.to_bits());
        assert_eq!(a.t_wall_s.to_bits(), b.t_wall_s.to_bits());
    }

    // The rendered analyses are complete and the JSON output parses.
    let text = csv.render_text();
    for section in ["tail evolution", "stage attribution", "faults:", "fairness"] {
        assert!(text.contains(section), "missing {section:?} in:\n{text}");
    }
    let json = csv.to_json().to_string();
    let parsed = fedpairing::util::json::Json::parse(&json).unwrap();
    assert_eq!(
        parsed.get("n_records").unwrap().as_usize().unwrap(),
        run.result.rounds.len()
    );
}
