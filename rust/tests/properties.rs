//! System-level property tests (via the from-scratch `util::proptest`
//! harness): invariants that must hold for *any* fleet, partition, or
//! matching — the L3 analogue of the hypothesis sweeps on the Python side.

use fedpairing::config::{DataDistribution, ExperimentConfig, PairingStrategy};
use fedpairing::data::partition::partition;
use fedpairing::nn;
use fedpairing::pairing::graph::{is_perfect_matching, ClientGraph};
use fedpairing::pairing::{exact, greedy, pair_clients};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::compute::split_lengths;
use fedpairing::sim::latency::{fedpairing_round, fl_round, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::proptest::{check, gen_pair, gen_u64, gen_usize, Gen};
use fedpairing::util::rng::Rng;

fn fleet_of(seed: u64, n: usize) -> (Fleet, Channel, ExperimentConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    cfg.seed = seed;
    cfg.samples_per_client = 128;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(seed));
    (fleet, Channel::new(cfg.channel), cfg)
}

#[test]
fn prop_every_strategy_yields_perfect_matching() {
    check(
        40,
        gen_pair(gen_u64(0, u64::MAX / 2), gen_usize(1, 10)),
        |&(seed, half)| {
            let n = half * 2;
            let (fleet, ch, cfg) = fleet_of(seed, n);
            let mut rng = Rng::new(seed ^ 1);
            [
                PairingStrategy::Greedy,
                PairingStrategy::Random,
                PairingStrategy::Location,
                PairingStrategy::Compute,
                PairingStrategy::Exact,
            ]
            .into_iter()
            .all(|s| {
                is_perfect_matching(
                    n,
                    &pair_clients(s, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng),
                )
            })
        },
    );
}

#[test]
fn prop_greedy_weight_between_half_and_full_optimum() {
    check(25, gen_pair(gen_u64(0, u64::MAX / 2), gen_usize(1, 8)), |&(seed, half)| {
        let n = half * 2;
        let (fleet, ch, cfg) = fleet_of(seed, n);
        let g = ClientGraph::build(&fleet, &ch, cfg.alpha, cfg.beta);
        let wg = g.matching_weight(&greedy::greedy_matching(&g));
        let we = g.matching_weight(&exact::exact_matching(&g));
        wg <= we + 1e-9 && 2.0 * wg + 1e-9 >= we
    });
}

#[test]
fn prop_split_lengths_partition_and_respect_speed() {
    check(
        100,
        Gen::new(|rng| {
            (
                rng.range_f64(0.05e9, 3e9),
                rng.range_f64(0.05e9, 3e9),
                2 + rng.below(30),
            )
        }),
        |&(fi, fj, w)| {
            let (li, lj) = split_lengths(fi, fj, w);
            // The floor in the paper's rule can hand the faster client one
            // layer *fewer* near a 50/50 split with odd W, so the honest
            // invariant is proximity to the unrounded ideal (within 1 layer,
            // modulo the [1, W-1] privacy clamp) — not strict ordering.
            let ideal = fi / (fi + fj) * w as f64;
            let clamped = ideal.max(1.0).min((w - 1) as f64);
            li + lj == w && li >= 1 && lj >= 1 && (li as f64 - clamped).abs() <= 1.0
        },
    );
}

#[test]
fn prop_partitions_conserve_samples_exactly() {
    check(
        40,
        Gen::new(|rng| {
            let dist = match rng.below(3) {
                0 => DataDistribution::Iid,
                1 => DataDistribution::ClassShards {
                    classes_per_client: 1 + rng.below(10),
                },
                _ => DataDistribution::Dirichlet {
                    alpha: rng.range_f64(0.05, 10.0),
                },
            };
            (rng.next_u64(), 1 + rng.below(20), 1 + rng.below(600), dist)
        }),
        |&(seed, n_clients, spc, dist)| {
            let mut rng = Rng::new(seed);
            let shards = partition(&mut rng, n_clients, spc, &dist);
            let total: usize = shards.iter().map(|s| s.len()).sum();
            let mut seen = std::collections::HashSet::new();
            let no_dup = shards
                .iter()
                .flat_map(|s| s.coords.iter())
                .all(|c| seen.insert(*c));
            total == n_clients * spc && no_dup && shards.iter().all(|s| s.len() == spc)
        },
    );
}

#[test]
fn prop_fedpairing_round_time_monotone_in_cpu_speed() {
    // Scaling every client's CPU up can never slow the round down.
    check(20, gen_u64(0, u64::MAX / 2), |&seed| {
        let (mut fleet, ch, cfg) = fleet_of(seed, 8);
        let profile = ModelProfile::resnet10_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: 1,
        };
        let pairs = pair_clients(
            PairingStrategy::Greedy,
            &fleet,
            &ch,
            cfg.alpha,
            cfg.beta,
            &mut Rng::new(seed),
        );
        let slow = fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, false);
        for f in fleet.freqs_hz.iter_mut() {
            *f *= 2.0;
        }
        let fast = fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, false);
        fast.total_s <= slow.total_s + 1e-9
    });
}

#[test]
fn prop_round_time_monotone_in_samples() {
    check(20, gen_pair(gen_u64(0, u64::MAX / 2), gen_usize(1, 400)), |&(seed, spc)| {
        let (mut fleet, ch, cfg) = fleet_of(seed, 6);
        let profile = ModelProfile::resnet10_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: 1,
        };
        fleet.n_samples = vec![spc; 6];
        let t1 = fl_round(&fleet, &profile, &sched, &ch, &cfg.compute, false).total_s;
        fleet.n_samples = vec![spc + 64; 6];
        let t2 = fl_round(&fleet, &profile, &sched, &ch, &cfg.compute, false).total_s;
        t2 > t1
    });
}

#[test]
fn prop_aggregation_preserves_mean_exactly() {
    // fedavg of identical models is the model; delta-sum of symmetric
    // perturbations cancels.
    check(
        30,
        Gen::new(|rng| {
            let t: Vec<Vec<f32>> = (0..6)
                .map(|_| (0..16).map(|_| rng.f32() * 2.0 - 1.0).collect())
                .collect();
            (t, rng.f32())
        }),
        |(model, delta)| {
            let n = 4;
            let weights = vec![1.0 / n as f64; n];
            let avg = nn::fedavg_weighted(&vec![model.clone(); n], &weights);
            let same = avg
                .iter()
                .zip(model)
                .all(|(a, b)| a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-6));
            // delta-sum cancellation
            let mut up = model.clone();
            nn::add_scaled(&mut up, model, *delta);
            let mut down = model.clone();
            nn::add_scaled(&mut down, model, -*delta);
            let mut g = model.clone();
            nn::aggregate_deltas(&mut g, &[up, down]);
            let cancel = g
                .iter()
                .zip(model)
                .all(|(a, b)| a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-5));
            same && cancel
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_configs() {
    check(
        40,
        Gen::new(|rng| {
            let mut cfg = ExperimentConfig::default();
            cfg.seed = rng.next_u64() >> 12;
            cfg.n_clients = 2 * (1 + rng.below(16));
            cfg.rounds = 1 + rng.below(200);
            cfg.lr = rng.f32() + 0.001;
            cfg.alpha = rng.f64() * 10.0;
            cfg.beta = rng.f64() * 1e-8;
            cfg.overlap_boost = rng.below(2) == 0;
            cfg.distribution = match rng.below(3) {
                0 => DataDistribution::Iid,
                1 => DataDistribution::ClassShards {
                    classes_per_client: 1 + rng.below(9),
                },
                _ => DataDistribution::Dirichlet {
                    alpha: 0.05 + rng.f64(),
                },
            };
            cfg
        }),
        |cfg| {
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&j).unwrap();
            back.to_json().to_string() == j.to_string()
        },
    );
}

#[test]
fn prop_channel_rate_antitone_in_distance() {
    check(
        60,
        Gen::new(|rng| (rng.range_f64(1.0, 200.0), rng.range_f64(0.0, 50.0))),
        |&(d, extra)| {
            let ch = Channel::new(ExperimentConfig::default().channel);
            ch.rate_at(d + extra) <= ch.rate_at(d) + 1e-9
        },
    );
}
