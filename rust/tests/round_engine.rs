//! Round-engine acceptance suite: the analytic kernels must match the DES
//! oracle across randomized configurations for all four algorithms
//! (`engine_matches_des`), and stable-scenario runs must be bit-identical
//! across cache state and any thread count.

use fedpairing::config::{
    Algorithm, EngineConfig, ExperimentConfig, RoundBackend, ScenarioConfig, ScenarioKind,
};
use fedpairing::fleet::simulate_scenario;
use fedpairing::sim::channel::Channel;
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::geometry::place_uniform_disk;
use fedpairing::sim::latency::{self, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::proptest::{check, gen_u64};
use fedpairing::util::rng::Rng;

/// Relative closeness at the acceptance tolerance (≤ 1e-9).
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-30)
}

fn all_close(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| close(x, y))
}

/// A fleet with heterogeneous frequencies, positions *and* shard sizes — so
/// the two directions of a pair run different batch counts.
fn random_fleet(rng: &mut Rng, n: usize) -> Fleet {
    let radius_m = 20.0 + rng.f64() * 80.0;
    Fleet {
        positions: place_uniform_disk(rng, n, radius_m),
        freqs_hz: (0..n).map(|_| rng.range_f64(0.05e9, 2.5e9)).collect(),
        n_samples: (0..n).map(|_| 16 + rng.below(300)).collect(),
    }
}

fn random_setup(seed: u64) -> (Fleet, ModelProfile, Schedule, Channel, ExperimentConfig) {
    let mut rng = Rng::new(seed);
    let n = 2 + rng.below(14);
    let fleet = random_fleet(&mut rng, n);
    let profile = if rng.below(2) == 0 {
        ModelProfile::resnet10_cifar()
    } else {
        ModelProfile::resnet18_cifar()
    };
    let sched = Schedule {
        batch_size: [8, 16, 32, 64][rng.below(4)],
        epochs: 1 + rng.below(3),
    };
    let mut cfg = ExperimentConfig::default();
    // Jitter the reference gain so the randomized `(f_i, f_j, batches, rate)`
    // space also sweeps the comm/compute balance.
    cfg.channel.ref_gain *= 10f64.powf(rng.range_f64(-1.0, 1.0));
    let channel = Channel::new(cfg.channel);
    (fleet, profile, sched, channel, cfg)
}

/// Shuffled near-perfect matching over the fleet (odd leftover goes solo).
fn random_matching(rng: &mut Rng, n: usize) -> (Vec<(usize, usize)>, Vec<usize>) {
    let mut ids: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut ids);
    let mut chunks = ids.chunks_exact(2);
    let pairs = chunks.by_ref().map(|c| (c[0], c[1])).collect();
    (pairs, chunks.remainder().to_vec())
}

fn analytic(threads: usize) -> RoundEngine {
    RoundEngine::new(&EngineConfig {
        backend: RoundBackend::Analytic,
        threads,
        flow_diagnostics: true,
    })
}

#[test]
fn engine_matches_des_fedpairing() {
    check(60, gen_u64(0, u64::MAX / 2), |&seed| {
        let (fleet, profile, sched, channel, cfg) = random_setup(seed);
        let (pairs, solos) = random_matching(&mut Rng::new(seed ^ 0xABCD), fleet.n());
        let mut eng = analytic(1);
        for include_upload in [false, true] {
            let a = eng.fedpairing_round(
                &fleet, &pairs, &solos, &profile, &sched, &channel, &cfg.compute, include_upload,
            );
            let d = latency::fedpairing_round_with_solos(
                &fleet, &pairs, &solos, &profile, &sched, &channel, &cfg.compute, include_upload,
            );
            if !(close(a.total_s, d.total_s)
                && close(a.max_cpu_busy_s, d.max_cpu_busy_s)
                && close(a.max_link_busy_s, d.max_link_busy_s)
                && all_close(&a.flow_finish_s, &d.flow_finish_s))
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn engine_matches_des_fl() {
    check(30, gen_u64(0, u64::MAX / 2), |&seed| {
        let (fleet, profile, sched, channel, cfg) = random_setup(seed);
        let mut eng = analytic(1);
        let a = eng.fl_round(&fleet, &profile, &sched, &channel, &cfg.compute, true);
        let d = latency::fl_round(&fleet, &profile, &sched, &channel, &cfg.compute, true);
        close(a.total_s, d.total_s) && all_close(&a.flow_finish_s, &d.flow_finish_s)
    });
}

#[test]
fn engine_matches_des_sl() {
    check(40, gen_u64(0, u64::MAX / 2), |&seed| {
        let (fleet, profile, sched, channel, cfg) = random_setup(seed);
        let mut rng = Rng::new(seed ^ 0x51);
        let cut = 1 + rng.below(profile.w() - 1);
        let server = rng.range_f64(5e9, 200e9);
        let mut eng = analytic(1);
        let a = eng.sl_round(&fleet, &profile, &sched, &channel, &cfg.compute, cut, server);
        let d = latency::sl_round(&fleet, &profile, &sched, &channel, &cfg.compute, cut, server);
        close(a.total_s, d.total_s)
            && close(a.max_cpu_busy_s, d.max_cpu_busy_s)
            && close(a.max_link_busy_s, d.max_link_busy_s)
            && all_close(&a.flow_finish_s, &d.flow_finish_s)
    });
}

#[test]
fn engine_matches_des_splitfed() {
    check(40, gen_u64(0, u64::MAX / 2), |&seed| {
        let (fleet, profile, sched, channel, cfg) = random_setup(seed);
        let mut rng = Rng::new(seed ^ 0x5F);
        let cut = 1 + rng.below(profile.w() - 1);
        let server = rng.range_f64(5e9, 200e9);
        let mut eng = analytic(1);
        for include_upload in [false, true] {
            let a = eng.splitfed_round(
                &fleet, &profile, &sched, &channel, &cfg.compute, cut, server, include_upload,
            );
            let d = latency::splitfed_round(
                &fleet, &profile, &sched, &channel, &cfg.compute, cut, server, include_upload,
            );
            if !(close(a.total_s, d.total_s)
                && close(a.max_cpu_busy_s, d.max_cpu_busy_s)
                && close(a.max_link_busy_s, d.max_link_busy_s)
                && all_close(&a.flow_finish_s, &d.flow_finish_s))
            {
                return false;
            }
        }
        true
    });
}

fn scenario_cfg(kind: ScenarioKind, algo: Algorithm, n: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = n;
    c.rounds = if n > 50 { 8 } else { 20 };
    c.samples_per_client = 200;
    c.algorithm = algo;
    c.scenario = ScenarioConfig::preset(kind);
    c
}

fn round_times(cfg: &ExperimentConfig) -> Vec<f64> {
    simulate_scenario(cfg)
        .unwrap()
        .result
        .rounds
        .iter()
        .map(|r| r.sim_round_s)
        .collect()
}

/// The tentpole bit-identity contract: with the cache warm (stable scenario,
/// rounds 2.. are 100 % hits) and for ANY `--threads` value, the analytic
/// engine reproduces the single-thread trace exactly — bit for bit, not
/// within a tolerance.
#[test]
fn stable_scenario_bit_identity_across_threads_and_cache() {
    // n = 170 → 85 pairs, past the engine's serial-evaluation threshold, so
    // round 1 genuinely runs on the pool.
    let base = scenario_cfg(ScenarioKind::Stable, Algorithm::FedPairing, 170);
    let reference = round_times(&base);
    // Cache proof: every stable round replays round 1's (computed) value.
    assert!(reference.iter().all(|t| t.to_bits() == reference[0].to_bits()));
    for threads in [2, 3, 8, 32] {
        let mut c = base.clone();
        c.engine.threads = threads;
        let times = round_times(&c);
        assert!(
            times.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
            "threads={threads} diverged from the single-thread trace"
        );
    }
}

/// Same contract under churn + fading: per-round partial cache hits and
/// parallel misses still reproduce the single-thread trace exactly.
#[test]
fn lossy_radio_bit_identity_across_threads() {
    let base = scenario_cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing, 170);
    let reference = round_times(&base);
    assert!(reference.windows(2).any(|w| w[0] != w[1]), "fading never moved round times");
    for threads in [2, 8] {
        let mut c = base.clone();
        c.engine.threads = threads;
        assert_eq!(round_times(&c), reference, "threads={threads}");
    }
}

/// The analytic engine is a drop-in for the DES across the whole scenario
/// pipeline, for all four algorithms.
#[test]
fn scenario_runs_match_des_backend_for_all_algorithms() {
    for algo in [
        Algorithm::FedPairing,
        Algorithm::VanillaFL,
        Algorithm::VanillaSL,
        Algorithm::SplitFed,
    ] {
        let analytic_cfg = scenario_cfg(ScenarioKind::LossyRadio, algo, 14);
        let mut des_cfg = analytic_cfg.clone();
        des_cfg.engine.backend = RoundBackend::Des;
        let a = round_times(&analytic_cfg);
        let d = round_times(&des_cfg);
        assert!(all_close(&a, &d), "{algo:?}: analytic {a:?} != des {d:?}");
    }
}

/// Metro-sized smoke (CI `scale` job runs this in release): a sparse-backend
/// churn scenario through the engine stays deterministic and fast enough to
/// run 5 rounds at n = 5 000 in a test.
#[test]
fn scale_metro_slice_runs_through_the_engine() {
    let mut cfg = ExperimentConfig::preset("metro-scale").unwrap();
    cfg.n_clients = if cfg!(debug_assertions) { 2_000 } else { 5_000 };
    cfg.rounds = 5;
    let a = round_times(&cfg);
    let b = round_times(&cfg);
    assert_eq!(a, b, "metro slice not deterministic");
    assert!(a.iter().all(|&t| t > 0.0));
}
