//! Property suite for the persistent cross-round matcher (DESIGN.md §10).
//!
//! The contract under test is absolute: after every fleet-dynamics epoch —
//! churn, mobility, straggler frequency flips, global shadowing — the
//! [`IncrementalMatcher`] must reproduce the batch rebuild
//! (`SparseCandidateGraph::over_members` + `match_candidates`) **bit for
//! bit**: same pairs in the same order, same solos, same live edge count.
//! That holds for every [`EdgeWeightSpec`] (including the co-designed
//! `SplitCost` objective) and for every thread count.
//!
//! The `scale_*` test is the acceptance path CI's release smoke job runs:
//! a million-client fleet (200k in debug so `cargo test -q` stays usable)
//! through initial pairing, a churn-repair epoch and one engine round,
//! with a wall-clock bound enforced in release.

use fedpairing::config::{ExperimentConfig, PairingMode, ScenarioConfig, ScenarioKind};
use fedpairing::fleet::{maintain_matching_session, FleetDynamics, PairingSession};
use fedpairing::pairing::{
    match_candidates, EdgeWeightSpec, IncrementalMatcher, SparseCandidateGraph,
};
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::latency::{Fleet, FleetView, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::split::SplitCostModel;
use fedpairing::util::index::InverseIndex;
use fedpairing::util::pool::FixedPool;
use fedpairing::util::proptest::{check, Gen};
use fedpairing::util::rng::Rng;

/// A scenario that moves everything the matcher watches: membership
/// (departures/rejoins), positions (mobility), frequencies (stragglers)
/// and the channel (shadowing).
fn churny_cfg(n: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    cfg.samples_per_client = 64;
    cfg.seed = seed;
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
    cfg.scenario.p_depart = 0.2;
    cfg.scenario.p_rejoin = 0.4;
    cfg.scenario.mobility_m = 4.0;
    cfg.scenario.p_straggle = 0.15;
    cfg.scenario.shadowing_std_db = 2.0;
    cfg
}

/// Drive `epochs` dynamics rounds, asserting the incremental matcher equals
/// the full rebuild after every one.
fn assert_tracks_rebuild(cfg: &ExperimentConfig, spec: EdgeWeightSpec<'_>, epochs: usize) {
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let (k_near, k_freq) = (cfg.backend.k_near, cfg.backend.k_freq);
    let mut matcher = IncrementalMatcher::new(dynamics.universe().n(), k_near, k_freq);
    let pool = FixedPool::new(1);
    for round in 1..=epochs {
        dynamics.step(round);
        let channel = dynamics.channel();
        let alive = dynamics.alive_indices();
        let inc = matcher
            .update(dynamics.universe(), &channel, dynamics.grid(), &alive, &spec, &pool)
            .clone();
        let g = SparseCandidateGraph::over_members(
            dynamics.universe(),
            &channel,
            dynamics.grid(),
            &alive,
            spec,
            k_near,
            k_freq,
        );
        let full = match_candidates(&g, &alive);
        assert_eq!(inc, full, "round {round}: matcher diverged from rebuild");
        assert_eq!(
            matcher.edge_count(),
            g.edges().len(),
            "round {round}: live edge set diverged"
        );
    }
}

#[test]
fn incremental_tracks_rebuild_eq5() {
    let cfg = churny_cfg(120, 11);
    let spec = EdgeWeightSpec::Eq5 {
        alpha: cfg.alpha,
        beta: cfg.beta,
    };
    assert_tracks_rebuild(&cfg, spec, 25);
}

#[test]
fn incremental_tracks_rebuild_neg_distance() {
    // Location baseline: geometric candidates only (no frequency band).
    assert_tracks_rebuild(&churny_cfg(120, 12), EdgeWeightSpec::NegDistance, 25);
}

#[test]
fn incremental_tracks_rebuild_freq_gap() {
    // Compute baseline: frequency-band candidates only (no grid scans).
    assert_tracks_rebuild(&churny_cfg(120, 13), EdgeWeightSpec::FreqGap, 25);
}

#[test]
fn incremental_tracks_rebuild_split_cost() {
    // Co-designed objective: weights come from the split planner's memoized
    // cut optimization — impure spec, serial weight evaluation.
    let cfg = churny_cfg(80, 14);
    let model = SplitCostModel::new(
        ModelProfile::from_preset(cfg.model),
        Schedule {
            batch_size: 32,
            epochs: cfg.local_epochs,
        },
        cfg.compute,
        cfg.split,
    );
    assert_tracks_rebuild(&cfg, EdgeWeightSpec::SplitCost(&model), 15);
}

#[test]
fn incremental_thread_counts_bit_identical() {
    // n past the parallel threshold so the initial epoch genuinely fans out
    // scans and weight evaluation over fixed-size chunks; later epochs mix
    // serial (small dirty sets) with the same merged ordering.
    let cfg = churny_cfg(6_000, 21);
    let specs = [
        EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        },
        EdgeWeightSpec::NegDistance,
        EdgeWeightSpec::FreqGap,
    ];
    for spec in specs {
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d1 = FleetDynamics::new(&cfg, base);
        let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let mut d4 = FleetDynamics::new(&cfg, base);
        let n = d1.universe().n();
        let mut m1 = IncrementalMatcher::new(n, cfg.backend.k_near, cfg.backend.k_freq);
        let mut m4 = IncrementalMatcher::new(n, cfg.backend.k_near, cfg.backend.k_freq);
        let p1 = FixedPool::new(1);
        let p4 = FixedPool::new(4);
        for round in 1..=5 {
            d1.step(round);
            d4.step(round);
            let (c1, c4) = (d1.channel(), d4.channel());
            let (a1, a4) = (d1.alive_indices(), d4.alive_indices());
            assert_eq!(a1, a4);
            let r1 = m1
                .update(d1.universe(), &c1, d1.grid(), &a1, &spec, &p1)
                .clone();
            let r4 = m4
                .update(d4.universe(), &c4, d4.grid(), &a4, &spec, &p4)
                .clone();
            assert_eq!(r1, r4, "{spec:?} round {round}: thread count leaked into result");
        }
    }
}

#[test]
fn incremental_matches_rebuild_on_random_traces() {
    // Randomized traces: fleet size, seed and scenario intensity all drawn
    // per case; every epoch of every case must match the rebuild exactly.
    check(
        12,
        Gen::new(|rng| {
            (
                30 + rng.below(120),
                rng.next_u64() % 10_000,
                rng.below(8) as f64,
            )
        }),
        |&(n, seed, mobility)| {
            let mut cfg = churny_cfg(n, seed);
            cfg.scenario.mobility_m = mobility;
            let spec = EdgeWeightSpec::Eq5 {
                alpha: cfg.alpha,
                beta: cfg.beta,
            };
            let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
            let mut dynamics = FleetDynamics::new(&cfg, base);
            let mut matcher =
                IncrementalMatcher::new(dynamics.universe().n(), cfg.backend.k_near, cfg.backend.k_freq);
            let pool = FixedPool::new(1);
            for round in 1..=8 {
                dynamics.step(round);
                let channel = dynamics.channel();
                let alive = dynamics.alive_indices();
                let inc = matcher
                    .update(dynamics.universe(), &channel, dynamics.grid(), &alive, &spec, &pool)
                    .clone();
                let g = SparseCandidateGraph::over_members(
                    dynamics.universe(),
                    &channel,
                    dynamics.grid(),
                    &alive,
                    spec,
                    cfg.backend.k_near,
                    cfg.backend.k_freq,
                );
                if inc != match_candidates(&g, &alive) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn incremental_skips_solve_when_nothing_moves() {
    // A frozen fleet (stable scenario, no shadowing) must short-circuit to
    // the cached matching: exactly one solve over any number of epochs.
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = 90;
    cfg.samples_per_client = 64;
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::Stable);
    let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(&cfg, base);
    let spec = EdgeWeightSpec::Eq5 {
        alpha: cfg.alpha,
        beta: cfg.beta,
    };
    let mut matcher =
        IncrementalMatcher::new(dynamics.universe().n(), cfg.backend.k_near, cfg.backend.k_freq);
    let pool = FixedPool::new(1);
    let mut first = None;
    for round in 1..=10 {
        dynamics.step(round);
        let channel = dynamics.channel();
        let alive = dynamics.alive_indices();
        let m = matcher
            .update(dynamics.universe(), &channel, dynamics.grid(), &alive, &spec, &pool)
            .clone();
        match &first {
            None => first = Some(m),
            Some(f) => assert_eq!(f, &m, "round {round}: cached matching drifted"),
        }
    }
    assert_eq!(matcher.solves, 1, "frozen fleet should solve exactly once");
}

#[test]
fn scale_incremental_million_client_round() {
    // The acceptance path: release runs the full 1M fleet; debug keeps
    // `cargo test -q` usable at 200k. Initial pairing through the
    // persistent matcher, one churn-repair epoch (O(affected), not a
    // rebuild), a full-rebuild cross-check, then one engine round.
    let n: usize = if cfg!(debug_assertions) { 200_000 } else { 1_000_000 };
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = n;
    cfg.seed = 17;
    cfg.pairing_mode = PairingMode::Incremental;
    let t0 = std::time::Instant::now();
    let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(&cfg, base);
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut session = PairingSession::new();

    // Round 1: initial pairing.
    let ev = dynamics.step(1);
    let channel = dynamics.channel();
    assert!(maintain_matching_session(
        &mut session,
        &dynamics,
        &ev,
        &channel,
        &cfg,
        None,
        &mut pairing_rng
    ));
    let alive = dynamics.alive_indices();
    {
        let m = session.matching.as_ref().unwrap();
        assert!(m.is_valid_over(&alive), "initial matching invalid");
        assert_eq!(m.pairs.len(), alive.len() / 2);
        assert_eq!(m.solos.len(), alive.len() % 2);
    }
    let t_init = t0.elapsed().as_secs_f64();

    // Round 2: churn-repair epoch.
    let ev = dynamics.step(2);
    assert!(
        !ev.departed.is_empty() || !ev.joined.is_empty(),
        "metro scenario produced no churn at n={n}"
    );
    let channel = dynamics.channel();
    let t1 = std::time::Instant::now();
    maintain_matching_session(
        &mut session,
        &dynamics,
        &ev,
        &channel,
        &cfg,
        None,
        &mut pairing_rng,
    );
    let t_repair = t1.elapsed().as_secs_f64();
    let alive = dynamics.alive_indices();
    let m = session.matching.clone().unwrap();
    assert!(m.is_valid_over(&alive), "repaired matching invalid");

    // Cross-check: the repaired epoch equals the from-scratch rebuild.
    let spec = EdgeWeightSpec::for_strategy_with(cfg.pairing, cfg.alpha, cfg.beta, None)
        .expect("metro strategy has a weight spec");
    let g = SparseCandidateGraph::over_members(
        dynamics.universe(),
        &channel,
        dynamics.grid(),
        &alive,
        spec,
        cfg.backend.k_near,
        cfg.backend.k_freq,
    );
    assert_eq!(m, match_candidates(&g, &alive), "incremental != rebuild at n={n}");

    // One engine round over the standing matching.
    let members = dynamics.present_members();
    let profile = ModelProfile::from_preset(cfg.model);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let mut engine = RoundEngine::new(&cfg.engine).with_split(cfg.split);
    let mut inv = InverseIndex::new();
    inv.rebuild(dynamics.universe().n(), members);
    let eff = m.restricted_to(members);
    let cpairs: Vec<(usize, usize)> = eff
        .pairs
        .iter()
        .map(|&(a, b)| (inv.compact(a), inv.compact(b)))
        .collect();
    let csolos: Vec<usize> = eff.solos.iter().map(|&s| inv.compact(s)).collect();
    let view = FleetView::new(dynamics.universe(), members);
    let rt = engine.fedpairing_round(
        &view,
        &cpairs,
        &csolos,
        &profile,
        &sched,
        &channel,
        &cfg.compute,
        true,
    );
    assert!(rt.total_s > 0.0, "engine round produced no time");
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "scale_incremental: n={n} init {t_init:.2}s, repair epoch {t_repair:.3}s, \
         total (incl. rebuild cross-check + engine round) {wall:.2}s"
    );
    if !cfg!(debug_assertions) {
        assert!(wall < 120.0, "1M acceptance too slow: {wall:.1}s");
    }
}
