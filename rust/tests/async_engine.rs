//! Asynchronous buffered-aggregation properties (DESIGN.md §9).
//!
//! The pinned invariants:
//!
//! 1. **Sync recovery** — with `staleness_cap` effectively unbounded and
//!    `buffer_size ≥ fleet`, every merge window degenerates to the lockstep
//!    barrier, and the async trace is *bit-identical* to the synchronous
//!    driver's: same `sim_round_s`, `sim_total_s`, `t_wall_s`, stage
//!    breakdowns and critical paths, at any thread count, for all four
//!    algorithms. `staleness_cap = 0` recovers the same barrier through the
//!    gate instead of the quorum.
//! 2. **Bounded staleness** — under churn with a small buffer and cap, no
//!    merge ever carries an update more than `staleness_cap` versions stale
//!    (gating, not clipping).
//! 3. **Event-count telemetry sampling** — buffered aggregation has no round
//!    cadence, so the sampler counts merge events; `sample_every = k` writes
//!    exactly `ceil(windows / k)` merge events to the JSONL stream.
//!
//! Every test serializes on one mutex: the telemetry registry gate is
//! process-wide and `Telemetry::new` (constructed by every scenario run)
//! flips it.

use fedpairing::config::{
    AggregationMode, Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind,
};
use fedpairing::coordinator::metrics::RoundRecord;
use fedpairing::fleet::simulate_scenario;
use fedpairing::telemetry::registry::{self, Counter};
use fedpairing::util::json::Json;
use std::sync::Mutex;

/// Process-wide serialization for the global registry gate.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

const N_CLIENTS: usize = 12;
const ROUNDS: usize = 30;

fn cfg(kind: ScenarioKind, algo: Algorithm) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = N_CLIENTS;
    c.rounds = ROUNDS;
    c.samples_per_client = 250;
    c.algorithm = algo;
    c.scenario = ScenarioConfig::preset(kind);
    c
}

/// The async counterpart of `base`: merge only once everything in flight has
/// arrived (quorum ≥ fleet, cap unbounded) — the sync-recovery limit.
fn recovery(base: &ExperimentConfig) -> ExperimentConfig {
    let mut c = base.clone();
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = c.n_clients;
    c.async_agg.staleness_cap = 1 << 30;
    c
}

const ALGOS: [Algorithm; 4] = [
    Algorithm::FedPairing,
    Algorithm::VanillaFL,
    Algorithm::VanillaSL,
    Algorithm::SplitFed,
];

/// Every observable bit of a round record except `staleness_mean`, which is
/// NaN on sync rows and 0.0 in the recovery limit by design (asserted
/// separately). NaN-safe: compares bit patterns.
type Fp = (usize, usize, u64, u64, u64, u64, [u64; 7], i64, i64, u64);

fn fingerprint(rounds: &[RoundRecord]) -> Vec<Fp> {
    rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.n_alive,
                r.sim_round_s.to_bits(),
                r.sim_total_s.to_bits(),
                r.t_wall_s.to_bits(),
                r.mean_cut.to_bits(),
                r.stages.stage_s.map(f64::to_bits),
                r.stages.crit_a,
                r.stages.crit_b,
                r.stages.crit_slack_s.to_bits(),
            )
        })
        .collect()
}

#[test]
fn async_recovery_is_bit_identical_to_sync_for_all_algorithms() {
    let _g = lock();
    for kind in [ScenarioKind::Stable, ScenarioKind::LossyRadio] {
        for algo in ALGOS {
            for threads in [1usize, 4] {
                let mut sync = cfg(kind, algo);
                sync.engine.threads = threads;
                let mut asy = recovery(&sync);
                asy.engine.threads = threads;
                let a = simulate_scenario(&sync).unwrap();
                let b = simulate_scenario(&asy).unwrap();
                assert_eq!(
                    fingerprint(&a.result.rounds),
                    fingerprint(&b.result.rounds),
                    "{kind:?}/{algo:?}/threads={threads}: recovery trace diverged"
                );
                assert_eq!(a.trace, b.trace, "{kind:?}/{algo:?}: churn trace diverged");
                // In the recovery limit every update is fresh and every
                // window merges the whole fleet's units with no one waiting.
                assert_eq!(b.events.len(), ROUNDS);
                for (ev, rec) in b.events.iter().zip(&b.result.rounds) {
                    assert_eq!(ev.staleness_max, 0, "{kind:?}/{algo:?}");
                    assert_eq!(ev.staleness_mean, 0.0);
                    assert_eq!(ev.n_running, 0);
                    assert!(ev.n_updates >= 1);
                    assert_eq!(ev.wait_eliminated_s, 0.0);
                    assert_eq!(rec.staleness_mean, 0.0);
                }
            }
        }
    }
}

#[test]
fn staleness_cap_zero_also_recovers_the_barrier() {
    let _g = lock();
    // cap = 0 defers every merge until nothing is running — the barrier
    // reached through the gate rather than the quorum. The buffer size is
    // irrelevant on this path.
    let sync = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    let mut asy = sync.clone();
    asy.aggregation = AggregationMode::Async;
    asy.async_agg.buffer_size = 1;
    asy.async_agg.staleness_cap = 0;
    let a = simulate_scenario(&sync).unwrap();
    let b = simulate_scenario(&asy).unwrap();
    assert_eq!(fingerprint(&a.result.rounds), fingerprint(&b.result.rounds));
    assert!(b.events.iter().all(|e| e.staleness_max == 0));
}

#[test]
fn all_algorithms_run_async_under_all_scenarios() {
    let _g = lock();
    for kind in ScenarioKind::ALL {
        for algo in ALGOS {
            let mut c = cfg(kind, algo);
            c.aggregation = AggregationMode::Async;
            c.async_agg.buffer_size = 3;
            c.async_agg.staleness_cap = 4;
            let run = simulate_scenario(&c).unwrap();
            assert_eq!(run.result.rounds.len(), ROUNDS, "{kind:?}/{algo:?}");
            assert_eq!(run.events.len(), ROUNDS, "{kind:?}/{algo:?}");
            let mut prev = 0.0f64;
            for (ev, rec) in run.events.iter().zip(&run.result.rounds) {
                assert!(ev.n_updates >= 1, "{kind:?}/{algo:?}: empty merge");
                assert!(ev.staleness_max <= 4, "{kind:?}/{algo:?}: cap violated");
                assert!(ev.staleness_mean >= 0.0 && ev.staleness_mean <= 4.0);
                assert!(ev.t_wall_s >= prev, "{kind:?}/{algo:?}: clock went back");
                prev = ev.t_wall_s;
                assert!(rec.sim_round_s >= 0.0);
                assert_eq!(rec.t_wall_s, ev.t_wall_s);
                assert_eq!(rec.staleness_mean.to_bits(), ev.staleness_mean.to_bits());
            }
        }
    }
}

#[test]
fn flash_crowd_merges_never_exceed_the_staleness_cap() {
    let _g = lock();
    // The acceptance-criteria path: a small quorum under churn merges early
    // and leaves stragglers in flight, yet the gate keeps every merged
    // update within the cap.
    let mut c = cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing);
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = 2;
    c.async_agg.staleness_cap = 2;
    let run = simulate_scenario(&c).unwrap();
    assert!(run.events.iter().all(|e| e.staleness_max <= 2));
    // Asynchrony actually happened: some merge carried a stale update, and
    // some merge fired while stragglers were still running (eliminating the
    // barrier wait they would have imposed).
    assert!(
        run.events.iter().any(|e| e.staleness_max > 0),
        "no merge ever saw a stale update — the run degenerated to sync"
    );
    assert!(run.events.iter().any(|e| e.wait_eliminated_s > 0.0));
    assert!(run.events.iter().any(|e| e.n_running > 0));
}

#[test]
fn synchronous_runs_report_no_aggregation_events() {
    let _g = lock();
    let run = simulate_scenario(&cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing)).unwrap();
    assert!(run.events.is_empty());
    for r in &run.result.rounds {
        assert!(r.staleness_mean.is_nan(), "sync rows carry no staleness");
        assert_eq!(r.t_wall_s.to_bits(), r.sim_total_s.to_bits());
    }
}

#[test]
fn async_runs_are_deterministic() {
    let _g = lock();
    let mut c = cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing);
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = 2;
    c.async_agg.staleness_cap = 2;
    let a = simulate_scenario(&c).unwrap();
    let b = simulate_scenario(&c).unwrap();
    assert_eq!(fingerprint(&a.result.rounds), fingerprint(&b.result.rounds));
    assert_eq!(a.events, b.events);
    assert_eq!(a.trace, b.trace);
}

/// Scratch directory for exporter output (inside `target/`, never committed).
fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("target/test-async-engine");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn event_sampling_writes_one_merge_event_per_sampled_window() {
    let _g = lock();
    // Regression: the sampler must count *merge events*, not rounds — with
    // no fixed round cadence, round-keyed sampling aliases against the merge
    // stream. 10 windows at sample_every = 2 → exactly 5 sampled events,
    // each contributing one "round" and one "merge" JSONL object.
    let trace_path = out_dir().join("sampled.trace.json");
    let trace_path = trace_path.to_str().unwrap().to_string();
    let mut c = cfg(ScenarioKind::LossyRadio, Algorithm::FedPairing);
    c.rounds = 10;
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = 2;
    c.async_agg.staleness_cap = 3;
    c.telemetry.enabled = true;
    c.telemetry.sample_every = 2;
    c.telemetry.trace_out = Some(trace_path.clone());
    let run = simulate_scenario(&c).unwrap();
    assert_eq!(run.events.len(), 10);
    let jsonl = std::fs::read_to_string(format!("{trace_path}.events.jsonl")).unwrap();
    let mut merges = 0usize;
    let mut rounds = 0usize;
    for line in jsonl.lines().filter(|l| !l.trim().is_empty()) {
        let obj = Json::parse(line).unwrap();
        match obj.get("type").and_then(Json::as_str) {
            Some("merge") => {
                merges += 1;
                assert!(obj.get("staleness_mean").is_some());
                assert!(obj.get("buffer_peak").is_some());
                assert!(obj.get("wait_eliminated_s").is_some());
            }
            Some("round") => rounds += 1,
            other => panic!("unexpected event type {other:?}"),
        }
    }
    assert_eq!(merges, 5, "sample_every=2 over 10 windows must export 5 merges");
    assert_eq!(rounds, 5);
    // The Chrome trace parses and carries counter ("C") samples for the
    // buffer-occupancy / staleness lanes alongside spans and metadata.
    let trace = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = trace.get("traceEvents").and_then(Json::as_arr).unwrap();
    let counters = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
        .count();
    // Two counter series per sampled merge.
    assert_eq!(counters, 10);
    registry::set_enabled(false);
    registry::reset();
}

#[test]
fn async_counters_populate_the_registry() {
    let _g = lock();
    registry::set_enabled(true);
    registry::reset();
    let mut c = cfg(ScenarioKind::FlashCrowd, Algorithm::FedPairing);
    c.aggregation = AggregationMode::Async;
    c.async_agg.buffer_size = 2;
    c.async_agg.staleness_cap = 2;
    c.telemetry.enabled = true;
    let run = simulate_scenario(&c).unwrap();
    let snap = registry::snapshot();
    assert_eq!(snap.counter(Counter::AsyncMerges.name()), ROUNDS as u64);
    let merged: usize = run.events.iter().map(|e| e.n_updates).sum();
    assert_eq!(
        snap.counter(Counter::AsyncUpdatesMerged.name()),
        merged as u64
    );
    registry::set_enabled(false);
    registry::reset();
}
