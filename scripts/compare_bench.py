#!/usr/bin/env python3
"""Compare the current BENCH_*.json emissions against a pinned baseline.

Usage: compare_bench.py BENCH_baseline.json [--tolerance-pct N]

The baseline file maps each bench JSON to the top-level metrics worth
pinning, each with a direction and a baseline value:

    {
      "tolerance_pct": 10,
      "metrics": {
        "BENCH_observatory.json": {
          "on_rounds_per_s":          {"direction": "higher", "baseline": null},
          "observatory_overhead_pct": {"direction": "lower",  "baseline": null}
        }
      }
    }

Semantics:

* ``baseline: null`` — record-only: the current value is printed so a
  maintainer can pin it, but it can never fail the job.
* ``direction: "higher"`` — bigger is better; fail when the current value
  drops below ``baseline * (1 - tol)``.
* ``direction: "lower"`` — smaller is better; fail when the current value
  rises above ``baseline * (1 + tol)``.

A missing bench file or metric key is a warning, not a failure, so the
comparison degrades gracefully when a bench is skipped. Exit code 1 iff at
least one pinned metric regressed beyond tolerance.
"""

import json
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def main(argv):
    if len(argv) < 2 or argv[1] in ("-h", "--help"):
        print(__doc__.strip())
        return 2
    baseline_path = argv[1]
    baseline = load(baseline_path)
    tol_pct = float(baseline.get("tolerance_pct", 10))
    for i, arg in enumerate(argv):
        if arg == "--tolerance-pct":
            tol_pct = float(argv[i + 1])
    tol = tol_pct / 100.0

    regressions = []
    warnings = []
    recorded = 0
    checked = 0

    for bench_file, metrics in sorted(baseline.get("metrics", {}).items()):
        try:
            current = load(bench_file)
        except (OSError, json.JSONDecodeError) as exc:
            warnings.append(f"{bench_file}: unreadable ({exc})")
            continue
        for key, spec in sorted(metrics.items()):
            direction = spec.get("direction", "higher")
            if direction not in ("higher", "lower"):
                warnings.append(f"{bench_file}:{key}: bad direction {direction!r}")
                continue
            value = current.get(key)
            if not isinstance(value, (int, float)):
                warnings.append(f"{bench_file}:{key}: missing or non-numeric")
                continue
            pinned = spec.get("baseline")
            if pinned is None:
                recorded += 1
                print(f"  record   {bench_file}:{key} = {value:.6g} ({direction} is better)")
                continue
            checked += 1
            if direction == "higher":
                limit = pinned * (1.0 - tol)
                bad = value < limit
            else:
                limit = pinned * (1.0 + tol)
                bad = value > limit
            verdict = "REGRESSED" if bad else "ok"
            print(
                f"  {verdict:<8} {bench_file}:{key} = {value:.6g} "
                f"(baseline {pinned:.6g}, limit {limit:.6g}, {direction} is better)"
            )
            if bad:
                regressions.append(f"{bench_file}:{key}")

    for w in warnings:
        print(f"  warn     {w}")
    print(
        f"compare_bench: {checked} checked, {recorded} record-only, "
        f"{len(warnings)} warnings, {len(regressions)} regressions "
        f"(tolerance {tol_pct:g}%)"
    )
    if regressions:
        print("REGRESSED metrics: " + ", ".join(regressions))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
