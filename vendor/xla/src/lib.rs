//! Stub of the `xla` crate's PJRT surface (substrate).
//!
//! The build environment has neither crates.io access nor the native XLA
//! extension library, so this crate provides the exact API shape
//! `fedpairing::runtime` compiles against, with every entry point returning
//! [`Error::Unavailable`]. The coordination layer (pairing, fleet dynamics,
//! latency simulation, metrics) is fully functional on the stub; only
//! artifact *execution* needs the real backend.
//!
//! To run the AOT artifacts for real, replace this path dependency in the
//! workspace `Cargo.toml` with the upstream `xla` crate and point
//! `XLA_EXTENSION_DIR` at a PJRT CPU build — no source changes required.

use std::fmt;

/// Stub failure: the native XLA backend is not linked.
#[derive(Debug, Clone)]
pub struct Error {
    what: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            what: format!(
                "{what}: XLA backend unavailable (stub build — see vendor/xla/src/lib.rs)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.what)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Device-resident buffer handle (never instantiated by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal value (never instantiated by the stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (never instantiated by the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (never instantiated by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] fails on the stub, so nothing
/// downstream of it can be reached.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_the_entry_point() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
