//! Vendored subset of the `anyhow` API (substrate — crates.io is unreachable
//! in the build environment; see DESIGN.md §2).
//!
//! Implements exactly what this repository uses: [`Error`] with a context
//! chain, [`Result`], the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. `{:#}` formatting renders
//! the full cause chain like upstream anyhow.

use std::fmt;

/// A dynamic error with an optional chain of contexts/causes.
///
/// Like upstream `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error`, which is what allows the blanket
/// `From<E: std::error::Error>` conversion below to coexist with the
/// reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(s) = &cur.source {
            cur = s;
        }
        cur
    }
}

/// Iterator over an [`Error`]'s cause chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, colon-separated (anyhow convention).
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std error's source chain into ours.
        let mut msgs: Vec<String> = Vec::new();
        msgs.push(e.to_string());
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(match out {
                None => Error::msg(m),
                Some(inner) => Error {
                    msg: m,
                    source: Some(Box::new(inner)),
                },
            });
        }
        out.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — format an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// `bail!("...")` — early-return a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// `ensure!(cond, "...")` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(format!("{}", e.root_cause()), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e}"), "plain msg");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("absent").is_err());
        assert_eq!(Some(3u8).context("absent").unwrap(), 3);
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("missing"));
    }
}
