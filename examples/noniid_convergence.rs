//! Figures 2 & 3 regeneration: convergence of FedPairing vs vanilla FL,
//! vanilla SL, and SplitFed under IID and Non-IID (2-class shard) data.
//!
//! Writes one CSV per (figure, algorithm) to `runs/` with the full accuracy
//! curve, and prints the final-accuracy comparison the paper reports
//! ("FedPairing improves on FL/SL/SplitFed by …").
//!
//! ```bash
//! cargo run --release --example noniid_convergence            # both figures
//! cargo run --release --example noniid_convergence -- --fig 3 # Non-IID only
//! cargo run --release --example noniid_convergence -- --rounds 40 --samples 256
//! ```

use fedpairing::cli::Command;
use fedpairing::config::{Algorithm, DataDistribution, ExperimentConfig};
use fedpairing::coordinator::run_experiment;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("noniid_convergence", "paper Figs. 2-3 driver")
        .flag("rounds", Some('r'), Some("N"), "communication rounds", Some("25"))
        .flag("samples", None, Some("N"), "samples per client", Some("192"))
        .flag("clients", Some('n'), Some("N"), "fleet size", Some("12"))
        .flag("seed", Some('s'), Some("N"), "seed", Some("17"))
        .flag("fig", None, Some("N"), "2 (IID), 3 (Non-IID), or both", Some("both"));
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match cmd.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            return Ok(());
        }
    };
    let rounds: usize = p.req("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let samples: usize = p.req("samples").map_err(|e| anyhow::anyhow!("{e}"))?;
    let clients: usize = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let which = p.get("fig").unwrap_or("both").to_string();

    let figs: Vec<(&str, DataDistribution)> = match which.as_str() {
        "2" => vec![("fig2", DataDistribution::Iid)],
        "3" => vec![(
            "fig3",
            DataDistribution::ClassShards { classes_per_client: 2 },
        )],
        _ => vec![
            ("fig2", DataDistribution::Iid),
            (
                "fig3",
                DataDistribution::ClassShards { classes_per_client: 2 },
            ),
        ],
    };
    let algos = [
        Algorithm::FedPairing,
        Algorithm::VanillaFL,
        Algorithm::VanillaSL,
        Algorithm::SplitFed,
    ];
    for (fig, dist) in figs {
        println!("\n=== {fig}: {} ===", dist.name());
        let mut finals = Vec::new();
        for algo in algos {
            let mut cfg = ExperimentConfig::default();
            cfg.name = fig.into();
            cfg.algorithm = algo;
            cfg.distribution = dist;
            cfg.rounds = rounds;
            cfg.samples_per_client = samples;
            cfg.n_clients = clients;
            cfg.seed = seed;
            cfg.test_samples = 600;
            let res = run_experiment(cfg)?;
            let (csv, _) = res.save("runs")?;
            println!(
                "  {:<12} final={:.4} best={:.4}  ({csv})",
                algo.name(),
                res.final_acc(),
                res.best_acc()
            );
            finals.push((algo, res.final_acc()));
        }
        let fp = finals[0].1;
        println!("  -- FedPairing improvement over:");
        for (algo, acc) in &finals[1..] {
            println!(
                "     {:<12} {:+.1} pp (paper {}: {})",
                algo.name(),
                (fp - acc) * 100.0,
                fig,
                match (fig, algo) {
                    ("fig2", Algorithm::VanillaFL) => "+4.1",
                    ("fig2", Algorithm::VanillaSL) => "+1.8",
                    ("fig2", Algorithm::SplitFed) => "+10.8",
                    ("fig3", Algorithm::VanillaFL) => "+5.3",
                    ("fig3", Algorithm::VanillaSL) => "+38.2",
                    ("fig3", Algorithm::SplitFed) => "+44.6",
                    _ => "-",
                }
            );
        }
    }
    Ok(())
}
