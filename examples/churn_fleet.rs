//! Fleet-dynamics showcase: every named scenario run through the engine-free
//! simulator at paper scale, plus a side-by-side of incremental matching
//! repair vs. full re-pairing.
//!
//! ```bash
//! cargo run --release --example churn_fleet
//! cargo run --release --example churn_fleet -- --rounds 100 --clients 20
//! ```

use fedpairing::cli::Command;
use fedpairing::config::{Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind};
use fedpairing::fleet::simulate_scenario;
use fedpairing::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("churn_fleet", "fleet-dynamics scenario driver")
        .flag("clients", Some('n'), Some("N"), "base fleet size", Some("20"))
        .flag("rounds", Some('r'), Some("N"), "communication rounds", Some("50"))
        .flag("seed", Some('s'), Some("N"), "experiment seed", Some("17"));
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match cmd.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            return Ok(());
        }
    };
    let clients: usize = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    let rounds: usize = p.req("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;

    println!(
        "FedPairing under fleet dynamics — {clients} clients, {rounds} rounds, seed {seed}\n"
    );
    println!(
        "{:<14} {:>10} {:>10} {:>8} {:>8} {:>10} {:>12} {:>12}",
        "scenario",
        "mean alive",
        "min/max",
        "departs",
        "joins",
        "repairs",
        "mean rnd s",
        "total sim s"
    );
    for kind in ScenarioKind::ALL {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = clients;
        cfg.rounds = rounds;
        cfg.seed = seed;
        cfg.algorithm = Algorithm::FedPairing;
        cfg.scenario = ScenarioConfig::preset(kind);
        cfg.name = format!("churn_{kind}");
        let run = simulate_scenario(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        let min = run.result.rounds.iter().map(|r| r.n_alive).min().unwrap_or(0);
        let max = run.result.rounds.iter().map(|r| r.n_alive).max().unwrap_or(0);
        let mut times = Summary::new();
        for r in &run.result.rounds {
            times.push(r.sim_round_s);
        }
        println!(
            "{:<14} {:>10.1} {:>10} {:>8} {:>8} {:>10} {:>12.0} {:>12.0}",
            kind.name(),
            run.mean_alive(),
            format!("{min}/{max}"),
            run.total_departures(),
            run.total_joins(),
            run.repaired_rounds,
            times.mean(),
            run.result.rounds.last().map(|r| r.sim_total_s).unwrap_or(0.0)
        );
    }

    println!("\nshape notes: `stable` reproduces the static paper fleet (alive is flat, no");
    println!("repairs); `flash-crowd` jumps to ~1.5x the base fleet at round 5; `diurnal`");
    println!("breathes with a 20-round period; `lossy-radio` churns hardest and its round");
    println!("times wander with the shadowing re-draws. Repairs touch only affected pairs —");
    println!("run with FEDPAIRING_LOG=info to watch each incremental re-pair.");
    Ok(())
}
