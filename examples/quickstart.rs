//! Quickstart: the smallest end-to-end FedPairing run.
//!
//! Four heterogeneous clients, a few rounds, real training through the AOT
//! artifacts (build them first: `make artifacts`), greedy pairing, and an
//! accuracy printout per round.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fedpairing::config::ExperimentConfig;
use fedpairing::coordinator::Experiment;

fn main() -> anyhow::Result<()> {
    // The `quick` preset: 4 clients, 64 samples each, 3 rounds.
    let mut cfg = ExperimentConfig::preset("quick").expect("preset");
    cfg.name = "quickstart".into();
    cfg.rounds = 5;
    cfg.samples_per_client = 128;
    cfg.test_samples = 256;

    println!("FedPairing quickstart — {} clients, {} rounds", cfg.n_clients, cfg.rounds);
    let mut exp = Experiment::new(cfg)?;

    // Show who got paired with whom and the split each pair uses.
    let w = exp.engine.meta().layers;
    println!("model: W={} layers, {} params", w, exp.engine.meta().n_params);

    let res = exp.run()?;
    println!("\nround  train_loss  test_acc  sim_time");
    for r in &res.rounds {
        println!(
            "{:>5}  {:>10.4}  {:>8.4}  {:>7.1}s",
            r.round, r.train_loss, r.test_acc, r.sim_round_s
        );
    }
    println!(
        "\nfinal accuracy: {:.1}%  (simulated total {:.0}s, host wall {:.1}s, {} artifact execs)",
        res.final_acc() * 100.0,
        res.rounds.last().map(|r| r.sim_total_s).unwrap_or(0.0),
        res.wall_s,
        res.total_execs
    );
    let (csv, _) = res.save("runs")?;
    println!("metrics written to {csv}");
    Ok(())
}
