//! End-to-end driver (DESIGN.md §7): the full three-layer stack on a real
//! small workload, proving all layers compose.
//!
//! 20 heterogeneous clients (the paper's fleet), synthetic CIFAR-like data,
//! the AOT-compiled ResNet-MLP (Pallas kernels inside), greedy pairing, and a
//! head-to-head FedPairing vs vanilla-FL comparison: loss curves, accuracy
//! curves, and simulated round times, all logged to `runs/`.
//!
//! Recorded in EXPERIMENTS.md §End-to-End.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! # smaller/faster:
//! cargo run --release --example e2e_train -- --rounds 10 --samples 128
//! ```

use fedpairing::cli::Command;
use fedpairing::config::{Algorithm, ExperimentConfig};
use fedpairing::coordinator::run_experiment;
use fedpairing::util::stats::linreg;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("e2e_train", "end-to-end FedPairing vs FL training")
        .flag("rounds", Some('r'), Some("N"), "communication rounds", Some("30"))
        .flag("samples", None, Some("N"), "samples per client", Some("256"))
        .flag("clients", Some('n'), Some("N"), "fleet size", Some("20"))
        .flag("seed", Some('s'), Some("N"), "seed", Some("17"));
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match cmd.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            return Ok(());
        }
    };
    let rounds: usize = p.req("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let samples: usize = p.req("samples").map_err(|e| anyhow::anyhow!("{e}"))?;
    let clients: usize = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut base = ExperimentConfig::default();
    base.name = "e2e".into();
    base.rounds = rounds;
    base.samples_per_client = samples;
    base.n_clients = clients;
    base.seed = seed;
    base.test_samples = 1000;

    println!(
        "=== end-to-end driver: {clients} clients × {samples} samples, {rounds} rounds ==="
    );
    let mut summaries = Vec::new();
    for algo in [Algorithm::FedPairing, Algorithm::VanillaFL] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        println!("\n--- {algo} ---");
        let t0 = std::time::Instant::now();
        let res = run_experiment(cfg)?;
        println!("round  train_loss  test_loss  test_acc  sim_total");
        for r in &res.rounds {
            if r.round == 1 || r.round % 5 == 0 || r.round == rounds {
                println!(
                    "{:>5}  {:>10.4}  {:>9.4}  {:>8.4}  {:>8.0}s",
                    r.round, r.train_loss, r.test_loss, r.test_acc, r.sim_total_s
                );
            }
        }
        // Convergence health: the training-loss trend must be negative.
        let xs: Vec<f64> = res.rounds.iter().map(|r| r.round as f64).collect();
        let ys: Vec<f64> = res.rounds.iter().map(|r| r.train_loss).collect();
        let (_, slope, _) = linreg(&xs, &ys);
        println!(
            "{algo}: final_acc={:.4} best={:.4} loss_slope={slope:.4}/round sim_round={:.0}s wall={:.0}s",
            res.final_acc(),
            res.best_acc(),
            res.mean_round_s(),
            t0.elapsed().as_secs_f64(),
        );
        let (csv, json) = res.save("runs")?;
        println!("saved {csv}, {json}");
        summaries.push((algo, res.final_acc(), res.mean_round_s()));
    }
    println!("\n=== summary (accuracy | simulated s/round) ===");
    for (algo, acc, rt) in &summaries {
        println!("  {:<12} {:>7.4} | {:>8.0}s", algo.name(), acc, rt);
    }
    let (fp, fl) = (&summaries[0], &summaries[1]);
    println!(
        "\nFedPairing is {:.1}× faster per simulated round than vanilla FL at comparable accuracy ({:.1}% vs {:.1}%).",
        fl.2 / fp.2,
        fp.1 * 100.0,
        fl.1 * 100.0
    );
    Ok(())
}
