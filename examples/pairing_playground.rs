//! Pairing playground: explore the eq. (5) objective.
//!
//! * α/β sweep — how the compute/comm tradeoff moves round time;
//! * greedy-vs-exact matching gap (weight and round-time);
//! * the split-length rule's balance quality across the fleet.
//!
//! ```bash
//! cargo run --release --example pairing_playground
//! ```

use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::pairing::{exact::exact_matching, graph::ClientGraph, greedy::greedy_matching, pair_clients};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::compute::{split_imbalance, split_lengths};
use fedpairing::sim::latency::{self, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::default();
    let mut rng = Rng::new(17);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule { batch_size: 32, epochs: 2 };

    println!("=== α/β sweep (greedy round time, 20-client fleet, seed 17) ===");
    println!("{:>8} {:>10} {:>12} {:>14}", "alpha", "beta", "round s", "matching ε");
    for &(alpha, beta) in &[
        (1.0, 0.0),     // compute-only (≡ compute-based baseline)
        (1.0, 1e-10),
        (1.0, 5e-10),   // default
        (1.0, 2e-9),
        (1.0, 1e-8),
        (0.0, 1.0),     // rate-only (≈ location-based)
    ] {
        let g = ClientGraph::build(&fleet, &ch, alpha, beta);
        let pairs = greedy_matching(&g);
        let rt = latency::fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, true);
        println!(
            "{alpha:>8} {beta:>10.0e} {:>10.0} s {:>14.3}",
            rt.total_s,
            g.matching_weight(&pairs)
        );
    }

    println!("\n=== greedy vs exact matching across fleet draws ===");
    println!("{:>6} {:>12} {:>12} {:>9} {:>12} {:>12}", "seed", "greedy ε", "exact ε", "ratio", "greedy s", "exact s");
    for seed in 0..8u64 {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = seed;
        let mut rng = Rng::new(seed);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let g = ClientGraph::build(&fleet, &ch, cfg.alpha, cfg.beta);
        let mg = greedy_matching(&g);
        let me = exact_matching(&g);
        let (wg, we) = (g.matching_weight(&mg), g.matching_weight(&me));
        let tg = latency::fedpairing_round(&fleet, &mg, &profile, &sched, &ch, &cfg.compute, true).total_s;
        let te = latency::fedpairing_round(&fleet, &me, &profile, &sched, &ch, &cfg.compute, true).total_s;
        println!("{seed:>6} {wg:>12.3} {we:>12.3} {:>9.4} {tg:>10.0} s {te:>10.0} s", wg / we);
    }
    println!("(note: exact maximizes ε, not round time — weight-optimal can be time-worse,");
    println!(" which is why the paper's greedy heuristic is not the bottleneck)");

    println!("\n=== split-length balance under the paper's rule (W=10) ===");
    println!("{:>10} {:>10} {:>8} {:>12}", "f_i GHz", "f_j GHz", "L_i/L_j", "imbalance");
    let mut rng = Rng::new(3);
    let pairs = pair_clients(PairingStrategy::Greedy, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng);
    for &(i, j) in pairs.iter().take(10) {
        let (fi, fj) = (fleet.freqs_hz[i], fleet.freqs_hz[j]);
        let (li, lj) = split_lengths(fi, fj, 10);
        println!(
            "{:>10.2} {:>10.2} {:>5}/{:<4} {:>11.1}%",
            fi / 1e9,
            fj / 1e9,
            li,
            lj,
            100.0 * split_imbalance(fi, fj, 10)
        );
    }
    Ok(())
}
