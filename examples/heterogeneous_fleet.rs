//! Tables I & II regeneration at full paper scale (20 clients × 2500 samples,
//! ResNet-18 cost profile, 2 local epochs) through the latency simulator —
//! exactly the numbers `cargo bench` reports, as a human-readable example.
//!
//! Prints single-draw tables (the paper reports one fleet realization) plus
//! multi-seed means so the reader can see which orderings are robust and
//! which are draw artifacts (EXPERIMENTS.md discusses both).
//!
//! ```bash
//! cargo run --release --example heterogeneous_fleet
//! cargo run --release --example heterogeneous_fleet -- --seeds 25
//! ```

use fedpairing::cli::Command;
use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::pairing::pair_clients;
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::{self, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::rng::Rng;
use fedpairing::util::stats::Summary;

const STRATEGIES: [PairingStrategy; 5] = [
    PairingStrategy::Greedy,
    PairingStrategy::Random,
    PairingStrategy::Location,
    PairingStrategy::Compute,
    PairingStrategy::Exact,
];

fn table_rows(cfg: &ExperimentConfig, seed: u64) -> ([f64; 5], [f64; 4]) {
    let profile = ModelProfile::resnet18_cifar();
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let mut t1 = [0f64; 5];
    for (i, strat) in STRATEGIES.iter().enumerate() {
        let pairs = pair_clients(*strat, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng.fork(7));
        t1[i] = latency::fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, true)
            .total_s;
    }
    let sf = latency::splitfed_round(
        &fleet,
        &profile,
        &sched,
        &ch,
        &cfg.compute,
        cfg.splitfed_cut_layer,
        cfg.compute.server_freq_ghz * 1e9,
        true,
    )
    .total_s;
    let fl = latency::fl_round(&fleet, &profile, &sched, &ch, &cfg.compute, true).total_s;
    let sl = latency::sl_round(
        &fleet,
        &profile,
        &sched,
        &ch,
        &cfg.compute,
        cfg.sl_cut_layer,
        cfg.compute.server_freq_ghz * 1e9,
    )
    .total_s;
    (t1, [t1[0], sf, fl, sl])
}

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("heterogeneous_fleet", "paper Tables I & II driver")
        .flag("seeds", None, Some("N"), "number of fleet draws to average", Some("10"))
        .flag("seed", Some('s'), Some("N"), "single-draw seed", Some("17"));
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match cmd.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            return Ok(());
        }
    };
    let n_seeds: u64 = p.req("seeds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = ExperimentConfig::default(); // 20 clients, 2500 samples, 2 epochs

    println!("paper setup: 20 clients, 50 m disk, ResNet-18 profile, 2500 samples, 2 epochs\n");
    let (t1, t2) = table_rows(&cfg, seed);
    println!("— Table I (single draw, seed {seed}) —      paper:");
    let paper1 = [1553.0, 4063.0, 7275.0, 1807.0, f64::NAN];
    for (i, s) in STRATEGIES.iter().enumerate() {
        println!(
            "  {:<22} {:>8.0} s    {:>8}",
            s.name(),
            t1[i],
            if paper1[i].is_nan() {
                "—".to_string()
            } else {
                format!("{:.0} s", paper1[i])
            }
        );
    }
    println!("\n— Table II (single draw, seed {seed}) —     paper:");
    let names2 = ["fedpairing", "splitfed", "vanilla_fl", "vanilla_sl"];
    let paper2 = [1553.0, 1798.0, 8716.0, 106.0];
    for i in 0..4 {
        println!("  {:<22} {:>8.0} s    {:>6.0} s", names2[i], t2[i], paper2[i]);
    }

    println!("\n— multi-draw means ± std over {n_seeds} fleets —");
    let mut sums1: Vec<Summary> = (0..5).map(|_| Summary::new()).collect();
    let mut sums2: Vec<Summary> = (0..4).map(|_| Summary::new()).collect();
    for s in 0..n_seeds {
        let (a, b) = table_rows(&cfg, 1000 + s);
        for i in 0..5 {
            sums1[i].push(a[i]);
        }
        for i in 0..4 {
            sums2[i].push(b[i]);
        }
    }
    for (i, s) in STRATEGIES.iter().enumerate() {
        println!(
            "  {:<22} {:>8.0} ± {:>5.0} s",
            s.name(),
            sums1[i].mean(),
            sums1[i].std()
        );
    }
    println!();
    for i in 0..4 {
        println!(
            "  {:<22} {:>8.0} ± {:>5.0} s",
            names2[i],
            sums2[i].mean(),
            sums2[i].std()
        );
    }
    println!("\nshape notes: greedy ≤ compute < random ≈ location on average; location-worst");
    println!("(paper) appears in individual draws like seed 17; vanilla SL pays eq.(3)-charged");
    println!("activation traffic the paper's 106 s figure omits — see EXPERIMENTS.md.");
    Ok(())
}
